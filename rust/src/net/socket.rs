//! `SocketTransport` — the [`Transport`] implementation that runs a
//! round's clients on remote worker processes over TCP, v3: one
//! event-driven poll loop per server, adaptive in-flight windows, and
//! hedged re-dispatch.
//!
//! ## Event-driven core
//!
//! The server owns **one** transport thread regardless of how many
//! workers connect. A [`Poller`] (epoll on Linux, a portable scan
//! fallback elsewhere) watches every worker socket plus the listener;
//! the poll loop drives a resumable [`FrameReader`] per connection
//! (non-blocking reads — a short read parks the partial frame, never
//! desynchronizes it), runs the pure [`Liveness`] state machine off
//! `bytes_consumed()`, and demultiplexes Outcome frames to the
//! dispatchers parked in [`SocketTransport::run_client`]. Replacement
//! workers handshake *under the same loop*: an accepted socket sits
//! in a handshake table until its Hello arrives (or its deadline
//! passes), so one half-open connector can never stall another
//! worker's rejoin — nor anything else.
//!
//! ## Sliding window & demultiplexing
//!
//! One connection per worker, up to its *window* of jobs in flight.
//! `run_cohort`'s threads call [`SocketTransport::run_client`]
//! concurrently; each call acquires a *slot* on the least-loaded live
//! connection, registers the job under its `(round, client, job_id)`
//! key, writes the Job frame, and parks on a private channel.
//! Out-of-order completion is invisible to the round loop:
//! `run_cohort`'s reorder buffer still feeds the streaming
//! aggregation in cohort order, so results stay bit-identical to the
//! in-process transport.
//!
//! With `--net-inflight adaptive` each connection's window starts at
//! 1 and grows additively as outcomes come back (one extra slot per
//! window-full of completions, capped), while a ≥4x latency spike
//! against the worker's own EWMA halves it — slow workers get fewer
//! jobs parked behind them, fast ones keep their pipelines full.
//!
//! ## Heartbeats
//!
//! When a connection has been silent past [`SocketCfg::heartbeat`]
//! the poll loop probes the worker (Heartbeat frame; workers answer
//! immediately even while computing, because their reader services
//! the socket during execution). If *nothing* arrives for
//! [`SocketCfg::io_timeout`] the connection is declared dead with the
//! typed [`WireError::HeartbeatLost`] — a silent partition can stall
//! a round for at most the idle deadline, never hang it.
//!
//! ## Straggler re-dispatch & hedging
//!
//! When a connection dies (read/write error, frame corruption, or
//! heartbeat loss), every job in flight on it is failed over: the
//! waiting dispatchers receive the typed [`ConnDied`] and re-dispatch
//! to a surviving connection (the determinism contract makes
//! re-execution bit-identical; workers that already computed the job
//! answer from their outcome cache). Only when no live connections
//! remain — or the re-dispatch budget is exhausted — does the error
//! surface, naming the client, round and worker.
//!
//! With [`SocketCfg::hedge`] non-zero, a dispatcher that has waited
//! that long *without* a failure duplicates the job onto a second
//! worker **before** any deadline: first answer wins, the loser's
//! slot is released immediately, and its eventual answer is dropped
//! as a duplicate. Both answers are bit-identical by the determinism
//! contract, so hedging can change latency but never results.
//!
//! Duplicate Outcome frames (network-level duplication, a hedge
//! loser, or a slow worker answering after its job was re-dispatched)
//! are ignored and counted — delivery is effectively at-least-once.
//! Their bytes land in a separate counter, never in `bytes_received`:
//! the reported uplink total counts each client's outcome exactly
//! once, keeping the paper's headline communication metric identical
//! to the fault-free run.
//!
//! [`WireError::HeartbeatLost`]: super::frame::WireError::HeartbeatLost

use std::collections::HashMap;
use std::fmt;
use std::io::ErrorKind;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use crate::coordinator::comm::Uplink;
use crate::coordinator::transport::{
    ClientJob, ClientOutcome, ShardDispatch, ShardReply, ShardSpec,
    Transport, WorkBuffers,
};

use super::codec::{self, Hello, PeerRole, WireOutcome, WireShardDone};
use super::frame::{
    self, Frame, FrameKind, FrameReader, Liveness, TickAction, WireError,
};
use super::poll::Poller;

/// Default adaptive-window growth cap (`--net-aimd-cap`) — deep
/// enough to hide wire latency on any realistic link, shallow enough
/// that one slow worker can't strand a whole cohort behind it.
pub const ADAPTIVE_MAX_WINDOW: usize = 32;

/// Default latency-spike multiplier (`--net-aimd-spike`): an outcome
/// slower than `spike x` the connection's own EWMA halves its window.
pub const AIMD_SPIKE_DEFAULT: u32 = 4;

/// Worker-side executor-thread hint when the server window is
/// adaptive (the worker can't know how far the window will grow).
const ADAPTIVE_EXEC_THREADS: usize = 4;

/// Per-connection in-flight window policy (`--net-inflight`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Inflight {
    /// Fixed window: at most N jobs in flight per connection.
    Fixed(usize),
    /// Start each connection at 1 and grow its window from the
    /// worker's observed outcome latency (additive growth, halving on
    /// a ≥4x latency spike, capped at [`ADAPTIVE_MAX_WINDOW`]).
    Adaptive,
}

impl Inflight {
    /// Window a fresh connection starts with.
    pub fn initial_window(self) -> usize {
        match self {
            Inflight::Fixed(n) => n,
            Inflight::Adaptive => 1,
        }
    }

    /// How many executor threads a worker should run to keep up with
    /// this window policy.
    pub fn exec_threads(self) -> usize {
        match self {
            Inflight::Fixed(n) => n.max(1),
            Inflight::Adaptive => ADAPTIVE_EXEC_THREADS,
        }
    }
}

impl fmt::Display for Inflight {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Inflight::Fixed(n) => write!(f, "{n}"),
            Inflight::Adaptive => write!(f, "adaptive"),
        }
    }
}

impl std::str::FromStr for Inflight {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Inflight, String> {
        match s {
            "adaptive" | "auto" => Ok(Inflight::Adaptive),
            _ => {
                let n: usize = s.parse().map_err(|_| {
                    format!("expected a window size or 'adaptive', got '{s}'")
                })?;
                if n == 0 {
                    return Err(
                        "in-flight window must be >= 1 (or 'adaptive')"
                            .to_string(),
                    );
                }
                Ok(Inflight::Fixed(n))
            }
        }
    }
}

/// Server-side transport tuning.
#[derive(Clone, Copy, Debug)]
pub struct SocketCfg {
    /// Per-read/write socket deadline AND the silence deadline after
    /// which a non-responsive connection is declared dead.
    pub io_timeout: Duration,
    /// Probe interval: a connection silent this long gets a Heartbeat.
    /// `Duration::ZERO` disables probing (silence then only kills a
    /// connection while jobs are pending on it).
    pub heartbeat: Duration,
    /// Sliding window policy: max in-flight jobs per worker
    /// connection.
    pub inflight: Inflight,
    /// Hedged re-dispatch: a job still unanswered after this long is
    /// duplicated onto a second worker (first answer wins).
    /// `Duration::ZERO` disables hedging.
    pub hedge: Duration,
    /// AIMD spike multiplier for the adaptive window
    /// (`--net-aimd-spike`, >= 2): an outcome slower than this many
    /// times the connection's latency EWMA halves its window.
    pub aimd_spike: u32,
    /// AIMD growth cap for the adaptive window (`--net-aimd-cap`,
    /// >= 1): windows never grow past this many in-flight jobs.
    pub aimd_cap: usize,
}

impl SocketCfg {
    /// Defaults around a single `--net-timeout-ms` value. The
    /// heartbeat is *derived* — `min(1 s, io_timeout / 4)` — so the
    /// probe-before-deadline invariant holds for every timeout, small
    /// ones included (the old fixed 1 s default made any
    /// `--net-timeout-ms <= 1000` fail at startup).
    pub fn new(io_timeout: Duration) -> SocketCfg {
        SocketCfg {
            io_timeout,
            heartbeat: Liveness::default_heartbeat(io_timeout),
            inflight: Inflight::Fixed(4),
            hedge: Duration::ZERO,
            aimd_spike: AIMD_SPIKE_DEFAULT,
            aimd_cap: ADAPTIVE_MAX_WINDOW,
        }
    }
}

/// How many times one job is re-dispatched after connection failures
/// before the error surfaces (each attempt lands on a *different*
/// connection — the dead one leaves the pool first).
const MAX_DISPATCH_ATTEMPTS: usize = 4;

/// Listener registration token — outside the connection-id space
/// (connection tokens count up from 0).
const LISTENER_TOKEN: u64 = u64::MAX;

/// Frames processed per connection per poll wakeup. Level-triggered
/// readiness re-reports a socket that still has bytes, so capping
/// keeps one firehose connection from starving the others without
/// ever losing data.
const MAX_FRAMES_PER_WAKE: usize = 32;

/// Deadline for small control writes (heartbeats, acks, shutdown
/// frames) issued from the poll loop — bounds how long one wedged
/// peer can stall the loop.
const CONTROL_WRITE_DEADLINE: Duration = Duration::from_millis(250);

/// Typed "the connection died" failure, fanned out to every job that
/// was in flight on it. The underlying [`WireError`] is shared, so
/// the chaos suite can assert the exact fault class for every victim.
#[derive(Clone, Debug)]
pub struct ConnDied {
    pub peer: String,
    pub error: Arc<WireError>,
}

impl fmt::Display for ConnDied {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "worker {} connection failed: {}",
            self.peer, self.error
        )
    }
}

impl std::error::Error for ConnDied {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        Some(self.error.as_ref())
    }
}

type PendingKey = (u32, u32, u32); // (round, client, job_id)
type PendingTx = mpsc::Sender<Result<WireOutcome, ConnDied>>;

type ShardKey = (u32, u64); // (round, shard lo)
type ShardTx = mpsc::Sender<Result<ShardReply, ConnDied>>;

/// One registered in-flight shard on an aggregator connection. The
/// protocol answers with a ShardDone (stats + EF) *then* the Partial;
/// the ShardDone is stashed here until the Partial completes the
/// pair. Same claim semantics as [`PendingEntry`].
struct ShardEntry {
    tx: ShardTx,
    claimed: Arc<AtomicBool>,
    done: Option<WireShardDone>,
}

/// One registered in-flight job: where to deliver the outcome, when
/// the Job frame went out (feeds the adaptive window), and the
/// claim flag shared by every route a hedged job rides on — the
/// first answer to swap it wins, so exactly one outcome per job is
/// ever aggregated or counted toward `bytes_received`, however the
/// two answers race.
struct PendingEntry {
    tx: PendingTx,
    sent_at: Instant,
    claimed: Arc<AtomicBool>,
}

/// One live worker connection.
struct Conn {
    id: u64,
    peer: String,
    /// Write half (cloned stream); all frame writes serialize here.
    writer: Mutex<TcpStream>,
    /// In-flight jobs awaiting their Outcome frames.
    pending: Mutex<HashMap<PendingKey, PendingEntry>>,
    /// In-flight shards awaiting their ShardDone + Partial pairs
    /// (aggregator pools only).
    shard_pending: Mutex<HashMap<ShardKey, ShardEntry>>,
    /// The shard the peer asked to own (`--shard i/G`); dispatch
    /// prefers the pinned connection but re-dispatches anywhere.
    shard_pin: Option<(u32, u32)>,
    /// Slots taken. Only mutated under the pool lock (see
    /// [`Shared::release_slot`] for why that makes the kill-race
    /// underflow impossible).
    in_flight: AtomicUsize,
    /// Current window cap (fixed, or adaptively grown/halved).
    window: AtomicUsize,
    /// EWMA of observed outcome latency in µs (adaptive mode only;
    /// 0 = no sample yet).
    lat_ewma_us: AtomicU64,
    /// Outcomes since the last window change (adaptive growth ladder).
    grown: AtomicU64,
    alive: AtomicBool,
}

struct Shared {
    cfg: SocketCfg,
    hello: Hello,
    /// Role every peer of this pool must announce — a homogeneous
    /// pool (all workers, or all mid-tier aggregators), validated at
    /// every handshake including replacements.
    expect: PeerRole,
    /// Live connections (a dead one is removed before its pending
    /// jobs are failed over).
    conns: Mutex<Vec<Arc<Conn>>>,
    /// Signalled when a slot frees, a connection joins, or one dies.
    slots: Condvar,
    next_conn_id: AtomicU64,
    next_nonce: AtomicU64,
    closed: AtomicBool,
    /// Job-frame bytes written (the downlink frame bytes; re-dispatch
    /// and hedge duplicates are counted — under faults or hedging,
    /// actual >= reported).
    bytes_sent: AtomicU64,
    /// Outcome-frame bytes read, counting only outcomes that matched
    /// a waiting job — each client's outcome exactly once. Duplicate
    /// bytes land in `duplicate_outcome_bytes` instead, so this stays
    /// identical to the fault-free uplink under any completable
    /// fault/hedge schedule.
    bytes_received: AtomicU64,
    /// Outcome frames that matched no pending job (duplicates /
    /// hedge losers / answers after a re-dispatch) — dropped by
    /// design.
    duplicate_outcomes: AtomicU64,
    /// Total frame bytes of those dropped outcomes.
    duplicate_outcome_bytes: AtomicU64,
    /// Heartbeat probes sent (liveness traffic, excluded from the
    /// CommStats byte identity).
    heartbeats_sent: AtomicU64,
    /// Matched Partial frame bytes received from aggregators — each
    /// shard's partial exactly once (duplicates land in the duplicate
    /// counters). Equals `CommStats.partial_bytes` for the run: the
    /// backbone reported-vs-framed identity.
    partial_bytes_received: AtomicU64,
    /// Jobs re-dispatched to a surviving worker after a failure.
    requeues: AtomicU64,
    /// Jobs duplicated onto a second worker by the hedge timer.
    hedges: AtomicU64,
    /// Job-frame bytes of those hedge duplicates (also included in
    /// `bytes_sent`).
    hedge_bytes: AtomicU64,
    /// Transport-owned threads (exactly one: the poll loop), joined
    /// on shutdown.
    threads: Mutex<Vec<JoinHandle<()>>>,
}

/// TCP-backed client-execution transport (server side).
pub struct SocketTransport {
    shared: Arc<Shared>,
}

/// Human noun for a pool's peers, for error and log text.
fn peer_noun(expect: PeerRole) -> &'static str {
    match expect {
        PeerRole::Worker => "worker",
        PeerRole::Aggregator => "aggregator",
    }
}

/// Validate a peer's opening frame against our Hello, returning its
/// decoded handshake (the shard pin rides in it). Pure — shared by
/// the blocking initial handshake and the poll loop's non-blocking
/// replacement handshake.
fn check_hello_frame(
    f: &Frame,
    peer: &str,
    hello: &Hello,
    expect: PeerRole,
) -> Result<codec::Hello> {
    let noun = peer_noun(expect);
    ensure!(
        f.kind == FrameKind::Hello,
        "{noun} {peer} opened with a {:?} frame, expected Hello",
        f.kind
    );
    let h = codec::decode_hello(&f.body)
        .with_context(|| format!("handshake with {noun} {peer}"))?;
    // auth gates everything else: an unauthenticated peer learns
    // nothing about our config beyond "the digest didn't match"
    if !codec::digest_eq(h.auth, hello.auth) {
        return Err(WireError::AuthRejected)
            .with_context(|| format!("handshake with {noun} {peer}"));
    }
    ensure!(
        h.fingerprint == hello.fingerprint,
        "config fingerprint mismatch with {noun} {peer}: server \
         {:#018x}, peer {:#018x} — launch every peer with the \
         identical preset and overrides",
        hello.fingerprint,
        h.fingerprint
    );
    ensure!(
        h.model == hello.model,
        "model mismatch with {noun} {peer}: server runs '{}', \
         peer runs '{}'",
        hello.model,
        h.model
    );
    ensure!(
        h.dim == hello.dim,
        "model dim mismatch with {noun} {peer}: server {}, peer {}",
        hello.dim,
        h.dim
    );
    // homogeneous pools: a worker must not handshake into an
    // aggregator backbone (or vice versa) — the frame protocols differ
    ensure!(
        h.role == expect,
        "peer {peer} connected as {:?}, but this listener accepts \
         {noun}s only",
        h.role
    );
    ensure!(
        h.shard.is_none() || h.role == PeerRole::Aggregator,
        "worker {peer} sent a shard pin — --shard only applies to \
         aggregators"
    );
    Ok(h)
}

/// Handshake one inbound peer stream in place — blocking I/O, used
/// only for the initial fleet (replacements handshake non-blocking
/// under the poll loop): validate its Hello against ours, ack it, and
/// install the socket deadlines. Returns the peer's decoded Hello.
fn handshake(
    stream: &mut TcpStream,
    peer: &str,
    hello: &Hello,
    io_timeout: Duration,
    expect: PeerRole,
) -> Result<codec::Hello> {
    let noun = peer_noun(expect);
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(io_timeout))
        .with_context(|| format!("setting {noun} read timeout"))?;
    stream
        .set_write_timeout(Some(io_timeout))
        .with_context(|| format!("setting {noun} write timeout"))?;
    let f = frame::read_frame(stream)
        .with_context(|| format!("handshake with {noun} {peer}"))?;
    let h = check_hello_frame(&f, peer, hello, expect)?;
    let mut ack = Vec::new();
    codec::encode_hello_ack(hello.fingerprint, hello.auth, &mut ack);
    frame::write_frame(stream, FrameKind::HelloAck, &ack)
        .with_context(|| format!("acking {noun} {peer}"))?;
    Ok(h)
}

/// Accept `n` initial worker connections from `listener`, handshake
/// each against `hello` (config fingerprint + model identity), and
/// build the transport around a single poll thread that owns every
/// connection plus the listener (so replacement workers can join
/// mid-run without a dedicated acceptor). Initial handshake failures
/// are hard errors (a mislaunched fleet must not start); replacement
/// handshake failures are logged and dropped.
pub fn accept_workers(
    listener: TcpListener,
    n: usize,
    hello: &Hello,
    cfg: SocketCfg,
) -> Result<SocketTransport> {
    accept_peers(listener, n, hello, cfg, PeerRole::Worker)
}

/// Accept `n` mid-tier aggregator connections (`--role aggregator`
/// peers) and build the root's backbone transport: rounds fan out
/// whole cohort shards ([`ShardSpec`]) instead of client jobs, and
/// the pool answers with ShardDone + Partial pairs. Same poll-loop
/// core, liveness and re-dispatch machinery as a worker pool.
pub fn accept_aggregators(
    listener: TcpListener,
    n: usize,
    hello: &Hello,
    cfg: SocketCfg,
) -> Result<SocketTransport> {
    accept_peers(listener, n, hello, cfg, PeerRole::Aggregator)
}

fn accept_peers(
    listener: TcpListener,
    n: usize,
    hello: &Hello,
    cfg: SocketCfg,
    expect: PeerRole,
) -> Result<SocketTransport> {
    ensure!(
        n >= 1,
        "need at least one {} connection",
        peer_noun(expect)
    );
    ensure!(
        !cfg.io_timeout.is_zero(),
        "worker io timeout must be non-zero"
    );
    ensure!(
        cfg.inflight.initial_window() >= 1,
        "per-connection window must be >= 1"
    );
    // probe-before-deadline invariant: with probing on, a peer must
    // be probed (and able to ack) before the idle deadline can fire —
    // otherwise long computations would be killed unprobed
    ensure!(
        cfg.heartbeat.is_zero() || cfg.heartbeat < cfg.io_timeout,
        "heartbeat interval ({:?}) must be shorter than the io \
         timeout ({:?}), or zero to disable probing",
        cfg.heartbeat,
        cfg.io_timeout
    );
    ensure!(
        cfg.hedge.is_zero() || cfg.hedge < cfg.io_timeout,
        "hedge delay ({:?}) must be shorter than the io timeout \
         ({:?}), or zero to disable hedging",
        cfg.hedge,
        cfg.io_timeout
    );
    let mut initial = Vec::with_capacity(n);
    for _ in 0..n {
        let (mut stream, peer) = listener.accept().with_context(|| {
            format!("accepting a {} connection", peer_noun(expect))
        })?;
        let peer = peer.to_string();
        let h =
            handshake(&mut stream, &peer, hello, cfg.io_timeout, expect)?;
        initial.push((stream, peer, h.shard));
    }
    let mut poller =
        Poller::new().context("creating the readiness poller")?;
    listener
        .set_nonblocking(true)
        .context("switching the listener to non-blocking accepts")?;
    poller
        .register_listener(&listener, LISTENER_TOKEN)
        .context("registering the listener with the poller")?;
    let shared = Arc::new(Shared {
        cfg,
        hello: hello.clone(),
        expect,
        conns: Mutex::new(Vec::new()),
        slots: Condvar::new(),
        next_conn_id: AtomicU64::new(0),
        next_nonce: AtomicU64::new(0),
        closed: AtomicBool::new(false),
        bytes_sent: AtomicU64::new(0),
        bytes_received: AtomicU64::new(0),
        duplicate_outcomes: AtomicU64::new(0),
        duplicate_outcome_bytes: AtomicU64::new(0),
        heartbeats_sent: AtomicU64::new(0),
        partial_bytes_received: AtomicU64::new(0),
        requeues: AtomicU64::new(0),
        hedges: AtomicU64::new(0),
        hedge_bytes: AtomicU64::new(0),
        threads: Mutex::new(Vec::new()),
    });
    let mut states: HashMap<u64, ConnState> = HashMap::new();
    for (stream, peer, pin) in initial {
        stream
            .set_nonblocking(true)
            .context("switching a peer connection to non-blocking")?;
        let reader = stream
            .try_clone()
            .context("cloning a peer connection for its reader")?;
        let token = shared.next_conn_id.fetch_add(1, Ordering::Relaxed);
        let conn = Arc::new(new_conn(&shared, token, peer, stream, pin));
        poller
            .register_stream(&reader, token)
            .context("registering a worker connection with the poller")?;
        shared.conns.lock().unwrap().push(conn.clone());
        states.insert(
            token,
            ConnState {
                conn,
                stream: reader,
                fr: FrameReader::new(),
                live: Liveness::new(cfg.heartbeat, cfg.io_timeout),
            },
        );
    }
    let sh = shared.clone();
    let h = thread::Builder::new()
        .name("fedfp8-net-poll".into())
        .spawn(move || poll_loop(&sh, poller, listener, states))
        .context("spawning the transport poll thread")?;
    shared.threads.lock().unwrap().push(h);
    Ok(SocketTransport { shared })
}

fn new_conn(
    shared: &Shared,
    id: u64,
    peer: String,
    writer: TcpStream,
    shard_pin: Option<(u32, u32)>,
) -> Conn {
    Conn {
        id,
        peer,
        writer: Mutex::new(writer),
        pending: Mutex::new(HashMap::new()),
        shard_pending: Mutex::new(HashMap::new()),
        shard_pin,
        in_flight: AtomicUsize::new(0),
        window: AtomicUsize::new(shared.cfg.inflight.initial_window()),
        lat_ewma_us: AtomicU64::new(0),
        grown: AtomicU64::new(0),
        alive: AtomicBool::new(true),
    }
}

/// Poll-loop state for one established connection: the read half plus
/// its resumable frame parser and liveness machine.
struct ConnState {
    conn: Arc<Conn>,
    stream: TcpStream,
    fr: FrameReader,
    live: Liveness,
}

/// Poll-loop state for one accepted-but-not-yet-handshaken socket. A
/// stalled half-connector sits here (costing nothing but a table
/// entry) until its Hello arrives or `io_timeout` expires — it can
/// never delay another connection's traffic or rejoin.
struct HsState {
    stream: TcpStream,
    peer: String,
    fr: FrameReader,
    started: Instant,
}

/// The server's single transport thread: readiness-driven reads on
/// every worker connection, replacement accepts + handshakes, probe
/// and deadline bookkeeping.
fn poll_loop(
    shared: &Arc<Shared>,
    mut poller: Poller,
    listener: TcpListener,
    mut conns: HashMap<u64, ConnState>,
) {
    let mut handshakes: HashMap<u64, HsState> = HashMap::new();
    let mut ready: Vec<u64> = Vec::new();
    let mut hb_body = Vec::new();
    let base_tick =
        Liveness::new(shared.cfg.heartbeat, shared.cfg.io_timeout).tick();
    while !shared.closed.load(Ordering::SeqCst) {
        let tick = if handshakes.is_empty() {
            base_tick
        } else {
            base_tick.min(Duration::from_millis(25))
        };
        if poller.wait(tick, &mut ready).is_err() {
            // wait only fails on programming-error class problems;
            // degrade to a timed scan instead of spinning
            thread::sleep(Duration::from_millis(5));
        }
        for i in 0..ready.len() {
            let token = ready[i];
            if token == LISTENER_TOKEN {
                accept_pending(
                    shared,
                    &mut poller,
                    &listener,
                    &mut handshakes,
                );
            } else if let Some(st) = conns.get_mut(&token) {
                drain_frames(shared, st, &mut hb_body);
            } else if handshakes.contains_key(&token) {
                drive_handshake(
                    shared,
                    &mut poller,
                    &mut handshakes,
                    &mut conns,
                    token,
                );
            }
            // stale token (connection reaped between wakeups): no-op
        }
        expire_handshakes(shared, &mut poller, &mut handshakes);
        // liveness pass + reaping, every tick for every connection
        conns.retain(|&token, st| {
            if !st.conn.alive.load(Ordering::SeqCst) {
                let _ = poller.deregister_stream(&st.stream, token);
                return false;
            }
            st.live.on_progress(st.fr.bytes_consumed());
            let has_pending = !st.conn.pending.lock().unwrap().is_empty()
                || !st.conn.shard_pending.lock().unwrap().is_empty();
            let probing = !shared.cfg.heartbeat.is_zero();
            match st.live.on_idle(has_pending || probing) {
                TickAction::Dead { idle_ms, deadline_ms } => {
                    kill_conn(
                        shared,
                        &st.conn,
                        WireError::HeartbeatLost { idle_ms, deadline_ms },
                    );
                    let _ = poller.deregister_stream(&st.stream, token);
                    false
                }
                TickAction::Probe => {
                    let nonce = shared
                        .next_nonce
                        .fetch_add(1, Ordering::Relaxed);
                    codec::encode_heartbeat(nonce, &mut hb_body);
                    // try_lock: a dispatcher mid-write must not stall
                    // the loop — its own frame is outgoing traffic,
                    // and a missed probe retries next interval
                    let res = match st.conn.writer.try_lock() {
                        Ok(mut w) => frame::write_frame_nb(
                            &mut *w,
                            FrameKind::Heartbeat,
                            &hb_body,
                            Instant::now() + CONTROL_WRITE_DEADLINE,
                        )
                        .map(Some),
                        Err(_) => Ok(None),
                    };
                    match res {
                        Ok(Some(_)) => {
                            shared
                                .heartbeats_sent
                                .fetch_add(1, Ordering::Relaxed);
                            true
                        }
                        Ok(None) => true,
                        Err(e) => {
                            kill_conn(shared, &st.conn, e);
                            let _ = poller
                                .deregister_stream(&st.stream, token);
                            false
                        }
                    }
                }
                TickAction::Idle => true,
            }
        });
    }
}

/// Drain the listener's accept backlog into the handshake table.
fn accept_pending(
    shared: &Shared,
    poller: &mut Poller,
    listener: &TcpListener,
    handshakes: &mut HashMap<u64, HsState>,
) {
    loop {
        match listener.accept() {
            Ok((stream, peer)) => {
                let peer = peer.to_string();
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                stream.set_nodelay(true).ok();
                let token =
                    shared.next_conn_id.fetch_add(1, Ordering::Relaxed);
                if poller.register_stream(&stream, token).is_err() {
                    continue;
                }
                handshakes.insert(
                    token,
                    HsState {
                        stream,
                        peer,
                        fr: FrameReader::new(),
                        started: Instant::now(),
                    },
                );
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return,
            Err(_) => return,
        }
    }
}

/// Pump one pending handshake: parse as much Hello as has arrived;
/// on a complete frame, validate + ack + promote to a live
/// connection.
fn drive_handshake(
    shared: &Arc<Shared>,
    poller: &mut Poller,
    handshakes: &mut HashMap<u64, HsState>,
    conns: &mut HashMap<u64, ConnState>,
    token: u64,
) {
    enum Ev {
        Pending,
        Frame(Frame),
        Fail(WireError),
    }
    let ev = {
        let Some(hs) = handshakes.get_mut(&token) else { return };
        match hs.fr.poll(&mut hs.stream) {
            Ok(None) => Ev::Pending,
            Ok(Some(f)) => Ev::Frame(f),
            Err(e) => Ev::Fail(e),
        }
    };
    match ev {
        Ev::Pending => {}
        Ev::Fail(e) => {
            let hs = handshakes.remove(&token).unwrap();
            let _ = poller.deregister_stream(&hs.stream, token);
            eprintln!(
                "[server] rejected replacement {} {}: {e:#}",
                peer_noun(shared.expect),
                hs.peer
            );
        }
        Ev::Frame(f) => {
            let hs = handshakes.remove(&token).unwrap();
            finish_handshake(shared, poller, conns, token, hs, f);
        }
    }
}

/// A replacement's Hello arrived: validate, ack, and install the
/// connection into the pool + poll state.
fn finish_handshake(
    shared: &Arc<Shared>,
    poller: &mut Poller,
    conns: &mut HashMap<u64, ConnState>,
    token: u64,
    mut hs: HsState,
    f: Frame,
) {
    let peer = hs.peer.clone();
    let noun = peer_noun(shared.expect);
    let h = match check_hello_frame(&f, &peer, &shared.hello, shared.expect)
    {
        Ok(h) => h,
        Err(e) => {
            let _ = poller.deregister_stream(&hs.stream, token);
            eprintln!(
                "[server] rejected replacement {noun} {peer}: {e:#}"
            );
            return;
        }
    };
    let mut ack = Vec::new();
    codec::encode_hello_ack(
        shared.hello.fingerprint,
        shared.hello.auth,
        &mut ack,
    );
    let ack_deadline = Instant::now()
        + shared.cfg.io_timeout.min(Duration::from_secs(1));
    if let Err(e) = frame::write_frame_nb(
        &mut hs.stream,
        FrameKind::HelloAck,
        &ack,
        ack_deadline,
    ) {
        let _ = poller.deregister_stream(&hs.stream, token);
        eprintln!(
            "[server] rejected replacement {noun} {peer}: acking \
             failed: {e}"
        );
        return;
    }
    let writer = match hs.stream.try_clone() {
        Ok(w) => w,
        Err(e) => {
            let _ = poller.deregister_stream(&hs.stream, token);
            eprintln!(
                "[server] rejected replacement {noun} {peer}: cloning \
                 its stream failed: {e}"
            );
            return;
        }
    };
    let conn =
        Arc::new(new_conn(shared, token, hs.peer, writer, h.shard));
    {
        let mut pool = shared.conns.lock().unwrap();
        // a replacement racing shutdown() must not be registered into
        // the already-drained pool (it would never get a Shutdown
        // frame)
        if shared.closed.load(Ordering::SeqCst) {
            drop(pool);
            let _ = poller.deregister_stream(&hs.stream, token);
            return;
        }
        pool.push(conn.clone());
    }
    shared.slots.notify_all();
    conns.insert(
        token,
        ConnState {
            conn,
            stream: hs.stream,
            fr: hs.fr,
            live: Liveness::new(
                shared.cfg.heartbeat,
                shared.cfg.io_timeout,
            ),
        },
    );
    eprintln!("[server] replacement {noun} {peer} joined");
}

/// Drop handshakes that outlived `io_timeout` without completing —
/// the half-open-connector bound.
fn expire_handshakes(
    shared: &Shared,
    poller: &mut Poller,
    handshakes: &mut HashMap<u64, HsState>,
) {
    let deadline = shared.cfg.io_timeout;
    handshakes.retain(|&token, hs| {
        if hs.started.elapsed() < deadline {
            return true;
        }
        let _ = poller.deregister_stream(&hs.stream, token);
        eprintln!(
            "[server] rejected replacement {} {}: handshake timed \
             out after {}ms",
            peer_noun(shared.expect),
            hs.peer,
            deadline.as_millis()
        );
        false
    });
}

/// Read frames off one ready connection until it would block (or the
/// per-wakeup cap).
fn drain_frames(shared: &Shared, st: &mut ConnState, hb_body: &mut Vec<u8>) {
    for _ in 0..MAX_FRAMES_PER_WAKE {
        if !st.conn.alive.load(Ordering::SeqCst) {
            return;
        }
        match st.fr.poll(&mut st.stream) {
            Ok(Some(f)) => process_frame(shared, &st.conn, f, hb_body),
            Ok(None) => return,
            Err(e) => {
                kill_conn(shared, &st.conn, e);
                return;
            }
        }
    }
}

/// Handle one complete inbound frame: demultiplex an Outcome to its
/// dispatcher, answer a worker's Heartbeat, validate an ack.
fn process_frame(
    shared: &Shared,
    conn: &Arc<Conn>,
    f: Frame,
    hb_body: &mut Vec<u8>,
) {
    match f.kind {
        FrameKind::Outcome => {
            let out = match codec::decode_outcome(&f.body) {
                Ok(o) => o,
                Err(e) => {
                    kill_conn(shared, conn, e);
                    return;
                }
            };
            let key: PendingKey = (out.round, out.client, out.job_id);
            let entry = conn.pending.lock().unwrap().remove(&key);
            match entry {
                Some(entry)
                    if !entry.claimed.swap(true, Ordering::SeqCst) =>
                {
                    // only the job's FIRST matched outcome counts
                    // toward the reported uplink — a duplicate's (or
                    // hedge loser's) bytes must not inflate the
                    // paper's headline communication metric
                    shared
                        .bytes_received
                        .fetch_add(f.total_bytes(), Ordering::Relaxed);
                    if shared.cfg.inflight == Inflight::Adaptive {
                        adapt_window(
                            &conn.window,
                            &conn.lat_ewma_us,
                            &conn.grown,
                            entry.sent_at.elapsed(),
                            shared.cfg.aimd_spike,
                            shared.cfg.aimd_cap,
                        );
                    }
                    shared.release_slot(conn);
                    let _ = entry.tx.send(Ok(out));
                }
                entry => {
                    // duplicated frame, a hedge loser (its own entry,
                    // but another route already claimed the job), or
                    // the answer to a job that was re-dispatched:
                    // bit-identical by the determinism contract, safe
                    // to drop — but its bytes are tracked
                    if entry.is_some() {
                        shared.release_slot(conn);
                    }
                    shared
                        .duplicate_outcomes
                        .fetch_add(1, Ordering::Relaxed);
                    shared
                        .duplicate_outcome_bytes
                        .fetch_add(f.total_bytes(), Ordering::Relaxed);
                }
            }
        }
        FrameKind::Heartbeat => {
            let nonce = match codec::decode_heartbeat(&f.body) {
                Ok(n) => n,
                Err(e) => {
                    kill_conn(shared, conn, e);
                    return;
                }
            };
            codec::encode_heartbeat(nonce, hb_body);
            let res = {
                let mut w = conn.writer.lock().unwrap();
                frame::write_frame_nb(
                    &mut *w,
                    FrameKind::HeartbeatAck,
                    hb_body,
                    Instant::now() + CONTROL_WRITE_DEADLINE,
                )
            };
            if let Err(e) = res {
                kill_conn(shared, conn, e);
            }
        }
        FrameKind::HeartbeatAck => {
            // liveness already refreshed via bytes_consumed
            if let Err(e) = codec::decode_heartbeat(&f.body) {
                kill_conn(shared, conn, e);
            }
        }
        FrameKind::ShardDone => {
            let d = match codec::decode_shard_done(&f.body) {
                Ok(d) => d,
                Err(e) => {
                    kill_conn(shared, conn, e);
                    return;
                }
            };
            let key: ShardKey = (d.round, d.lo);
            let mut sp = conn.shard_pending.lock().unwrap();
            match sp.get_mut(&key) {
                // stash the stats half; the Partial completes the pair
                Some(entry) => entry.done = Some(d),
                None => {
                    // the answer to a shard that was re-dispatched
                    // elsewhere: bit-identical by construction, drop
                    drop(sp);
                    shared
                        .duplicate_outcomes
                        .fetch_add(1, Ordering::Relaxed);
                    shared
                        .duplicate_outcome_bytes
                        .fetch_add(f.total_bytes(), Ordering::Relaxed);
                }
            }
        }
        FrameKind::Partial => {
            let (round, partial) = match codec::decode_partial(&f.body) {
                Ok(p) => p,
                Err(e) => {
                    kill_conn(shared, conn, e);
                    return;
                }
            };
            let key: ShardKey = (round, partial.start);
            let entry = conn.shard_pending.lock().unwrap().remove(&key);
            match entry {
                Some(entry)
                    if !entry.claimed.swap(true, Ordering::SeqCst) =>
                {
                    // protocol order: the ShardDone (stats + EF) must
                    // precede its Partial on the same connection
                    let Some(done) = entry.done else {
                        kill_conn(
                            shared,
                            conn,
                            WireError::Malformed {
                                what: format!(
                                    "Partial for shard [{}, {}) arrived \
                                     before its ShardDone",
                                    partial.start, partial.end
                                ),
                            },
                        );
                        return;
                    };
                    // each shard's partial exactly once — the backbone
                    // byte identity mirror of `bytes_received`
                    shared
                        .partial_bytes_received
                        .fetch_add(f.total_bytes(), Ordering::Relaxed);
                    shared.release_slot(conn);
                    let _ = entry.tx.send(Ok(ShardReply {
                        partial,
                        up_bytes: done.up_bytes,
                        up_msgs: done.up_msgs,
                        efs: done.efs,
                    }));
                }
                entry => {
                    if entry.is_some() {
                        shared.release_slot(conn);
                    }
                    shared
                        .duplicate_outcomes
                        .fetch_add(1, Ordering::Relaxed);
                    shared
                        .duplicate_outcome_bytes
                        .fetch_add(f.total_bytes(), Ordering::Relaxed);
                }
            }
        }
        k => {
            kill_conn(
                shared,
                conn,
                WireError::Malformed {
                    what: format!(
                        "unexpected {k:?} frame from a {}",
                        peer_noun(shared.expect)
                    ),
                },
            );
        }
    }
}

/// AIMD window update from one observed outcome latency: grow by one
/// slot per window-full of completions, halve on a `>= spike`x jump
/// against the connection's own EWMA, never grow past `cap`
/// (`--net-aimd-spike` / `--net-aimd-cap`; defaults
/// [`AIMD_SPIKE_DEFAULT`] / [`ADAPTIVE_MAX_WINDOW`]). Free function
/// over the atomics so the policy is unit-testable without sockets.
fn adapt_window(
    window: &AtomicUsize,
    lat_ewma_us: &AtomicU64,
    grown: &AtomicU64,
    latency: Duration,
    spike: u32,
    cap: usize,
) {
    let us = latency.as_micros().clamp(1, u64::MAX as u128) as u64;
    let prior = lat_ewma_us.load(Ordering::Relaxed);
    let ewma = if prior == 0 {
        us
    } else {
        (prior - prior / 8 + us / 8).max(1)
    };
    lat_ewma_us.store(ewma, Ordering::Relaxed);
    if prior != 0 && us > prior.saturating_mul(spike as u64) {
        // latency spike: halve (floor 1) and restart the growth ladder
        let w = window.load(Ordering::SeqCst);
        window.store((w / 2).max(1), Ordering::SeqCst);
        grown.store(0, Ordering::Relaxed);
        return;
    }
    let w = window.load(Ordering::SeqCst);
    if w >= cap {
        return;
    }
    let g = grown.fetch_add(1, Ordering::Relaxed) + 1;
    if g as usize >= w {
        window.store(w + 1, Ordering::SeqCst);
        grown.store(0, Ordering::Relaxed);
    }
}

/// Declare a connection dead: remove it from the pool, fail over its
/// in-flight jobs, and close the socket. Idempotent.
fn kill_conn(shared: &Shared, conn: &Arc<Conn>, error: WireError) {
    if !conn.alive.swap(false, Ordering::SeqCst) {
        return;
    }
    {
        let mut conns = shared.conns.lock().unwrap();
        conns.retain(|c| c.id != conn.id);
        // zero the slot count under the pool lock: a concurrent
        // releaser holds the same lock across its alive-check +
        // decrement, so it either ran before this store (fine — the
        // store wins) or observes alive == false and skips. Underflow
        // is impossible.
        conn.in_flight.store(0, Ordering::SeqCst);
    }
    let died = ConnDied {
        peer: conn.peer.clone(),
        error: Arc::new(error),
    };
    let victims: Vec<PendingTx> = {
        let mut pending = conn.pending.lock().unwrap();
        pending.drain().map(|(_, e)| e.tx).collect()
    };
    for tx in victims {
        let _ = tx.send(Err(died.clone()));
    }
    let shard_victims: Vec<ShardTx> = {
        let mut sp = conn.shard_pending.lock().unwrap();
        sp.drain().map(|(_, e)| e.tx).collect()
    };
    for tx in shard_victims {
        let _ = tx.send(Err(died.clone()));
    }
    let _ = conn.writer.lock().unwrap().shutdown(Shutdown::Both);
    shared.slots.notify_all();
}

impl Shared {
    /// Acquire a dispatch slot: the least-loaded live connection with
    /// a free window position. Blocks while the pool is saturated;
    /// fails fast when no live connections remain.
    fn acquire(&self) -> Result<Arc<Conn>> {
        let mut conns = self.conns.lock().unwrap();
        loop {
            ensure!(
                !self.closed.load(Ordering::SeqCst),
                "transport is shut down"
            );
            ensure!(
                !conns.is_empty(),
                "no live worker connections left (all were discarded \
                 after errors)"
            );
            if let Some(c) = Self::pick_least_loaded(&conns, &[]) {
                c.in_flight.fetch_add(1, Ordering::SeqCst);
                return Ok(c);
            }
            conns = self.slots.wait(conns).unwrap();
        }
    }

    /// Acquire a dispatch slot for a shard: the connection that
    /// *pinned* this shard (`--shard i/G`) if it is live and has a
    /// free window position, else the least-loaded live connection —
    /// so a dead pinned aggregator's shard re-dispatches to any
    /// survivor. Blocks while the pool is saturated.
    fn acquire_shard(&self, pin: (u32, u32)) -> Result<Arc<Conn>> {
        let mut conns = self.conns.lock().unwrap();
        loop {
            ensure!(
                !self.closed.load(Ordering::SeqCst),
                "transport is shut down"
            );
            ensure!(
                !conns.is_empty(),
                "no live aggregator connections left (all were \
                 discarded after errors)"
            );
            let pinned = conns.iter().find(|c| {
                c.shard_pin == Some(pin)
                    && c.in_flight.load(Ordering::SeqCst)
                        < c.window.load(Ordering::SeqCst)
            });
            if let Some(c) = pinned.cloned() {
                c.in_flight.fetch_add(1, Ordering::SeqCst);
                return Ok(c);
            }
            if let Some(c) = Self::pick_least_loaded(&conns, &[]) {
                c.in_flight.fetch_add(1, Ordering::SeqCst);
                return Ok(c);
            }
            conns = self.slots.wait(conns).unwrap();
        }
    }

    /// Non-blocking acquire for hedged dispatch, skipping connections
    /// the job already rides on.
    fn try_acquire_excluding(
        &self,
        exclude: &[Arc<Conn>],
    ) -> Option<Arc<Conn>> {
        let conns = self.conns.lock().unwrap();
        if self.closed.load(Ordering::SeqCst) {
            return None;
        }
        let c = Self::pick_least_loaded(&conns, exclude)?;
        c.in_flight.fetch_add(1, Ordering::SeqCst);
        Some(c)
    }

    /// Least-loaded scan, reading each connection's `(in_flight,
    /// window)` exactly once. The old `filter(...).min_by_key(...)`
    /// double-load raced a concurrent free/acquire into picking a
    /// connection already at its window.
    fn pick_least_loaded(
        conns: &[Arc<Conn>],
        exclude: &[Arc<Conn>],
    ) -> Option<Arc<Conn>> {
        let mut best: Option<(Arc<Conn>, usize)> = None;
        for c in conns {
            if exclude.iter().any(|e| e.id == c.id) {
                continue;
            }
            let load = c.in_flight.load(Ordering::SeqCst);
            let cap = c.window.load(Ordering::SeqCst);
            if load >= cap {
                continue;
            }
            let better = match &best {
                Some((_, b)) => load < *b,
                None => true,
            };
            if better {
                best = Some((c.clone(), load));
            }
        }
        best.map(|(c, _)| c)
    }

    /// Release one previously-acquired slot. The alive check and the
    /// decrement happen under the pool lock — the same lock
    /// `kill_conn` holds for its `in_flight` zeroing — so a release
    /// racing a kill can never underflow the counter.
    fn release_slot(&self, conn: &Conn) {
        {
            let _pool = self.conns.lock().unwrap();
            if conn.alive.load(Ordering::SeqCst) {
                conn.in_flight.fetch_sub(1, Ordering::SeqCst);
            }
        }
        self.slots.notify_all();
    }
}

impl SocketTransport {
    /// Total Job-frame bytes sent to workers so far (re-dispatched
    /// and hedged frames included).
    pub fn bytes_sent(&self) -> u64 {
        self.shared.bytes_sent.load(Ordering::Relaxed)
    }

    /// Total matched Outcome-frame bytes received from workers so far
    /// (each client's outcome exactly once; duplicates are tracked
    /// separately).
    pub fn bytes_received(&self) -> u64 {
        self.shared.bytes_received.load(Ordering::Relaxed)
    }

    /// Live worker connections (diagnostics / tests).
    pub fn live_workers(&self) -> usize {
        self.shared.conns.lock().unwrap().len()
    }

    /// Outcome frames ignored because no job was waiting for them.
    pub fn duplicate_outcomes(&self) -> u64 {
        self.shared.duplicate_outcomes.load(Ordering::Relaxed)
    }

    /// Total frame bytes of those ignored outcomes.
    pub fn duplicate_outcome_bytes(&self) -> u64 {
        self.shared.duplicate_outcome_bytes.load(Ordering::Relaxed)
    }

    /// Heartbeat probes this side has sent.
    pub fn heartbeats_sent(&self) -> u64 {
        self.shared.heartbeats_sent.load(Ordering::Relaxed)
    }

    /// Matched Partial frame bytes received over the aggregator
    /// backbone — each shard's partial exactly once. Equals the run's
    /// `CommStats.partial_bytes` (the reported-vs-framed identity,
    /// asserted by tests/tree_net.rs).
    pub fn partial_bytes_received(&self) -> u64 {
        self.shared.partial_bytes_received.load(Ordering::Relaxed)
    }

    /// Jobs re-dispatched to a surviving worker after a connection
    /// failure.
    pub fn requeues(&self) -> u64 {
        self.shared.requeues.load(Ordering::Relaxed)
    }

    /// Jobs duplicated onto a second worker by the hedge timer.
    pub fn hedges(&self) -> u64 {
        self.shared.hedges.load(Ordering::Relaxed)
    }

    /// Job-frame bytes those hedges added (subset of `bytes_sent`).
    pub fn hedge_bytes(&self) -> u64 {
        self.shared.hedge_bytes.load(Ordering::Relaxed)
    }

    /// Threads the transport runs — exactly one (the poll loop),
    /// independent of how many workers are connected. Asserted in
    /// tests as the O(1)-threads guarantee.
    pub fn transport_threads(&self) -> usize {
        self.shared.threads.lock().unwrap().len()
    }

    /// Politely close every connection (Shutdown frame + socket
    /// close) so workers exit their serve loops, then stop the poll
    /// thread. Idempotent; also runs on Drop.
    pub fn shutdown(&self) {
        let shared = &self.shared;
        if shared.closed.swap(true, Ordering::SeqCst) {
            return;
        }
        let conns: Vec<Arc<Conn>> = {
            let mut pool = shared.conns.lock().unwrap();
            pool.drain(..).collect()
        };
        for conn in conns {
            {
                let mut w = conn.writer.lock().unwrap();
                let _ = frame::write_frame_nb(
                    &mut *w,
                    FrameKind::Shutdown,
                    &[],
                    Instant::now() + CONTROL_WRITE_DEADLINE,
                );
                let _ = w.shutdown(Shutdown::Both);
            }
            conn.alive.store(false, Ordering::SeqCst);
            // any pending jobs at shutdown (there should be none: the
            // round loop completes before shutdown) fail over cleanly
            let victims: Vec<PendingTx> = conn
                .pending
                .lock()
                .unwrap()
                .drain()
                .map(|(_, e)| e.tx)
                .collect();
            let shard_victims: Vec<ShardTx> = conn
                .shard_pending
                .lock()
                .unwrap()
                .drain()
                .map(|(_, e)| e.tx)
                .collect();
            let died = ConnDied {
                peer: conn.peer.clone(),
                error: Arc::new(WireError::CleanClose),
            };
            for tx in victims {
                let _ = tx.send(Err(died.clone()));
            }
            for tx in shard_victims {
                let _ = tx.send(Err(died.clone()));
            }
        }
        shared.slots.notify_all();
        // the poll thread observes `closed` within one tick and exits
        loop {
            let threads: Vec<JoinHandle<()>> = {
                let mut t = shared.threads.lock().unwrap();
                t.drain(..).collect()
            };
            if threads.is_empty() {
                break;
            }
            for h in threads {
                let _ = h.join();
            }
        }
    }
}

impl Drop for SocketTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Register `key` on `conn` and write its Job frame. Returns whether
/// this route can still produce a message on `tx` (false means the
/// connection died around the dispatch *and* we reclaimed the entry
/// ourselves, so nothing will ever arrive for it).
fn dispatch_on(
    shared: &Shared,
    conn: &Arc<Conn>,
    key: PendingKey,
    tx: &PendingTx,
    claimed: &Arc<AtomicBool>,
    body: &[u8],
    is_hedge: bool,
) -> bool {
    conn.pending.lock().unwrap().insert(
        key,
        PendingEntry {
            tx: tx.clone(),
            sent_at: Instant::now(),
            claimed: claimed.clone(),
        },
    );
    let write_res = {
        let mut w = conn.writer.lock().unwrap();
        frame::write_frame_nb(
            &mut *w,
            FrameKind::Job,
            body,
            Instant::now() + shared.cfg.io_timeout,
        )
    };
    match write_res {
        Ok(n) => {
            shared.bytes_sent.fetch_add(n, Ordering::Relaxed);
            if is_hedge {
                shared.hedge_bytes.fetch_add(n, Ordering::Relaxed);
            }
        }
        Err(e) => {
            // kill_conn drains pending (including ours), so the
            // dispatcher's recv resolves immediately
            kill_conn(shared, conn, e);
        }
    }
    // race guard: if the connection died *around* our insert
    // (kill_conn may already have drained pending before the entry
    // landed), reclaim the entry ourselves — no drain will ever send
    // for it
    if !conn.alive.load(Ordering::SeqCst)
        && conn.pending.lock().unwrap().remove(&key).is_some()
    {
        return false;
    }
    true
}

/// Register `key` on an aggregator connection and write its Shard
/// frame. Same contract and race guard as [`dispatch_on`].
fn dispatch_shard_on(
    shared: &Shared,
    conn: &Arc<Conn>,
    key: ShardKey,
    tx: &ShardTx,
    claimed: &Arc<AtomicBool>,
    body: &[u8],
) -> bool {
    conn.shard_pending.lock().unwrap().insert(
        key,
        ShardEntry {
            tx: tx.clone(),
            claimed: claimed.clone(),
            done: None,
        },
    );
    let write_res = {
        let mut w = conn.writer.lock().unwrap();
        frame::write_frame_nb(
            &mut *w,
            FrameKind::Shard,
            body,
            Instant::now() + shared.cfg.io_timeout,
        )
    };
    match write_res {
        Ok(n) => {
            shared.bytes_sent.fetch_add(n, Ordering::Relaxed);
        }
        Err(e) => {
            kill_conn(shared, conn, e);
        }
    }
    if !conn.alive.load(Ordering::SeqCst)
        && conn.shard_pending.lock().unwrap().remove(&key).is_some()
    {
        return false;
    }
    true
}

impl ShardDispatch for SocketTransport {
    /// Dispatch one cohort shard to an aggregator connection and wait
    /// for its ShardDone + Partial pair. No hedging: a shard is a
    /// whole sub-round, so duplicating it doubles real work — faults
    /// are handled by the same re-dispatch budget as client jobs (the
    /// shard geometry is configured, so a survivor executing a dead
    /// peer's shard produces bit-identical sums).
    fn run_shard(&self, spec: &ShardSpec<'_>) -> Result<ShardReply> {
        let shared = &self.shared;
        let (round, lo, hi) = (spec.round, spec.lo, spec.hi);
        let key: ShardKey = (round, lo);
        let mut body = Vec::new();
        codec::encode_shard_parts(
            round, spec.index, spec.nodes, lo, hi, spec.down, &spec.efs,
            &mut body,
        );
        let mut last_err: Option<anyhow::Error> = None;
        for attempt in 0..MAX_DISPATCH_ATTEMPTS {
            let conn = match shared.acquire_shard((spec.index, spec.nodes))
            {
                Ok(c) => c,
                Err(e) => {
                    let e = match last_err.take() {
                        Some(prior) => prior.context(e.to_string()),
                        None => e,
                    };
                    return Err(e.context(format!(
                        "shard [{lo}, {hi}) round {round}: dispatch \
                         failed"
                    )));
                }
            };
            if attempt > 0 {
                shared.requeues.fetch_add(1, Ordering::Relaxed);
            }
            let (tx, rx) = mpsc::channel();
            let claimed = Arc::new(AtomicBool::new(false));
            let mut live =
                usize::from(dispatch_shard_on(
                    shared, &conn, key, &tx, &claimed, &body,
                ));
            let mut winner: Option<ShardReply> = None;
            while live > 0 {
                match rx.recv_timeout(shared.cfg.io_timeout) {
                    Ok(Ok(reply)) => {
                        winner = Some(reply);
                        break;
                    }
                    Ok(Err(died)) => {
                        live -= 1;
                        let peer = died.peer.clone();
                        last_err = Some(
                            anyhow::Error::from(died).context(format!(
                                "shard [{lo}, {hi}) round {round} via \
                                 aggregator {peer}"
                            )),
                        );
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if conn.alive.load(Ordering::SeqCst) {
                            // a shard is a whole sub-round: legitimate
                            // long execution, bounded by the liveness
                            // machinery (probes + idle deadline), not
                            // by this wait
                            continue;
                        }
                        // the connection died without our entry being
                        // drained: reclaim it, then pick up any
                        // message already sent
                        if conn
                            .shard_pending
                            .lock()
                            .unwrap()
                            .remove(&key)
                            .is_some()
                        {
                            live = live.saturating_sub(1);
                        }
                        while let Ok(msg) = rx.try_recv() {
                            match msg {
                                Ok(reply) => {
                                    winner = Some(reply);
                                    break;
                                }
                                Err(_) => {
                                    live = live.saturating_sub(1);
                                }
                            }
                        }
                        break;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            // release the slot if the entry is still registered (on
            // success process_frame already released it)
            if conn.shard_pending.lock().unwrap().remove(&key).is_some() {
                shared.release_slot(&conn);
            }
            if let Some(reply) = winner {
                ensure!(
                    reply.partial.start == lo && reply.partial.end == hi,
                    "aggregator {} answered for cohort range [{}, {}), \
                     expected [{lo}, {hi})",
                    conn.peer,
                    reply.partial.start,
                    reply.partial.end,
                );
                return Ok(reply);
            }
            if last_err.is_none() {
                last_err = Some(anyhow!(
                    "shard [{lo}, {hi}) round {round} via aggregator \
                     {}: connection reader exited without a result",
                    conn.peer
                ));
            }
        }
        Err(last_err
            .unwrap_or_else(|| anyhow!("shard dispatch failed"))
            .context(format!(
                "shard [{lo}, {hi}) round {round}: re-dispatch budget \
                 ({MAX_DISPATCH_ATTEMPTS} attempts) exhausted"
            )))
    }
}

impl Transport for SocketTransport {
    fn run_client(
        &self,
        job: ClientJob<'_>,
        buffers: &mut WorkBuffers,
    ) -> Result<ClientOutcome> {
        let shared = &self.shared;
        ensure!(
            shared.expect == PeerRole::Worker,
            "this transport fronts mid-tier aggregators; client jobs \
             are dispatched as whole shards, never individually"
        );
        let (client, round) = (job.client, job.round);
        let key: PendingKey =
            (round as u32, client as u32, job.job_id);
        // reuse the cohort worker's wire scratch: one payload-sized
        // allocation per dispatcher thread for the life of the run,
        // not one per message (encode_job_from clears it first)
        let body = &mut buffers.wire;
        codec::encode_job_from(&job, body);
        let hedge = shared.cfg.hedge;
        let mut last_err: Option<anyhow::Error> = None;
        for attempt in 0..MAX_DISPATCH_ATTEMPTS {
            let conn = match shared.acquire() {
                Ok(c) => c,
                Err(e) => {
                    // no live workers: surface the fault that got us
                    // here (the pool-empty message alone hides it)
                    let e = match last_err.take() {
                        Some(prior) => prior.context(e.to_string()),
                        None => e,
                    };
                    return Err(e.context(format!(
                        "client {client} round {round}: dispatch failed"
                    )));
                }
            };
            if attempt > 0 {
                shared.requeues.fetch_add(1, Ordering::Relaxed);
            }
            let (tx, rx) = mpsc::channel();
            let claimed = Arc::new(AtomicBool::new(false));
            // every connection this job currently rides on; [0] is
            // the primary, a hedge may add a second
            let mut routes: Vec<Arc<Conn>> = Vec::with_capacity(2);
            let mut live_routes = 0usize;
            if dispatch_on(shared, &conn, key, &tx, &claimed, body, false)
            {
                live_routes += 1;
            }
            routes.push(conn.clone());
            let started = Instant::now();
            let mut hedged = false;
            // one re-hedge budget per dispatch attempt: if a route
            // dies while a hedge is outstanding, the hedge may be
            // re-armed once — without this, losing the hedge
            // connection silently demotes the job back to a single
            // route racing the very straggler the hedge was for
            let mut rehedges_left = 1usize;
            let mut winner: Option<WireOutcome> = None;
            // wait for the first answer, re-checking route health on
            // every io_timeout tick. Legitimate long computations are
            // unbounded by design — the worker's reader acks probes
            // while executing — but if every route dies without our
            // entry being drained (a failure mode this guards
            // against), we reclaim it instead of parking forever.
            'wait: while live_routes > 0 {
                if !hedged
                    && !hedge.is_zero()
                    && started.elapsed() >= hedge
                {
                    // straggler: duplicate the job onto a second
                    // worker before any deadline — first answer wins
                    hedged = true;
                    if let Some(h) = shared.try_acquire_excluding(&routes)
                    {
                        shared.hedges.fetch_add(1, Ordering::Relaxed);
                        if dispatch_on(
                            shared, &h, key, &tx, &claimed, body, true,
                        ) {
                            live_routes += 1;
                        }
                        routes.push(h);
                    }
                }
                let wait = if hedged || hedge.is_zero() {
                    shared.cfg.io_timeout
                } else {
                    // wake exactly at the hedge point
                    hedge
                        .saturating_sub(started.elapsed())
                        .max(Duration::from_millis(1))
                        .min(shared.cfg.io_timeout)
                };
                match rx.recv_timeout(wait) {
                    Ok(Ok(out)) => {
                        winner = Some(out);
                        break 'wait;
                    }
                    Ok(Err(died)) => {
                        live_routes -= 1;
                        let peer = died.peer.clone();
                        last_err = Some(
                            anyhow::Error::from(died).context(format!(
                                "client {client} round {round} via \
                                 worker {peer}"
                            )),
                        );
                        // a route died after the hedge fired (either
                        // side of the race): re-arm the hedge once so
                        // the job keeps two horses. The next loop
                        // iteration re-fires immediately — the hedge
                        // deadline already elapsed — and the dead
                        // conn stays in `routes`, so
                        // try_acquire_excluding picks a third
                        // connection. The loser of the new race
                        // lands in the existing duplicate
                        // accounting, exactly like a first hedge.
                        if hedged
                            && rehedges_left > 0
                            && live_routes > 0
                        {
                            rehedges_left -= 1;
                            hedged = false;
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if routes
                            .iter()
                            .any(|c| c.alive.load(Ordering::SeqCst))
                        {
                            continue;
                        }
                        // every route is dead: reclaim entries a
                        // drain race may have orphaned, then pick up
                        // any message already sent
                        for c in &routes {
                            if c.pending
                                .lock()
                                .unwrap()
                                .remove(&key)
                                .is_some()
                            {
                                live_routes =
                                    live_routes.saturating_sub(1);
                            }
                        }
                        while let Ok(msg) = rx.try_recv() {
                            match msg {
                                Ok(out) => {
                                    winner = Some(out);
                                    break 'wait;
                                }
                                Err(_) => {
                                    live_routes =
                                        live_routes.saturating_sub(1);
                                }
                            }
                        }
                        break 'wait;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        // unreachable — we hold a tx — but never park
                        break 'wait;
                    }
                }
            }
            // release every slot the job still holds: on success this
            // frees the hedge loser immediately (its late answer then
            // counts as a duplicate); on failure it cleans the routes
            // up for the next attempt
            for c in &routes {
                if c.pending.lock().unwrap().remove(&key).is_some() {
                    shared.release_slot(c);
                }
            }
            if let Some(out) = winner {
                ensure!(
                    out.client as usize == client
                        && out.round as usize == round,
                    "worker answered for client {} round {}, \
                     expected client {client} round {round}",
                    out.client,
                    out.round,
                );
                ensure!(
                    out.n_k == job.n_k,
                    "worker reported n_k {} for client {client}, \
                     server expected {} — worlds out of sync \
                     despite matching fingerprints?",
                    out.n_k,
                    job.n_k
                );
                return Ok(ClientOutcome {
                    uplink: Uplink {
                        payload: out.payload,
                        client,
                        n_k: out.n_k,
                        mean_loss: out.mean_loss,
                    },
                    ef: out.ef,
                });
            }
            if last_err.is_none() {
                last_err = Some(anyhow!(
                    "client {client} round {round} via worker {}: \
                     connection reader exited without a result",
                    conn.peer
                ));
            }
        }
        Err(last_err
            .unwrap_or_else(|| anyhow!("dispatch failed"))
            .context(format!(
                "client {client} round {round}: re-dispatch budget \
                 ({MAX_DISPATCH_ATTEMPTS} attempts) exhausted"
            )))
    }

    /// An aggregator pool dispatches whole shards — the round loop
    /// routes through [`ShardDispatch::run_shard`] instead of
    /// per-client jobs.
    fn shard_dispatcher(
        &self,
    ) -> Option<&dyn crate::coordinator::transport::ShardDispatch> {
        if self.shared.expect == PeerRole::Aggregator {
            Some(self)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::str::FromStr;

    #[test]
    fn socket_cfg_derives_heartbeat_from_timeout() {
        // the satellite-4 regression: small --net-timeout-ms values
        // must yield a probe interval below the deadline, not a
        // startup failure
        let cfg = SocketCfg::new(Duration::from_millis(800));
        assert_eq!(cfg.heartbeat, Duration::from_millis(200));
        let cfg = SocketCfg::new(Duration::from_millis(1000));
        assert_eq!(cfg.heartbeat, Duration::from_millis(250));
        // large timeouts keep the historical 1 s probe cadence
        let cfg = SocketCfg::new(Duration::from_secs(30));
        assert_eq!(cfg.heartbeat, Duration::from_millis(1000));
        // every derived config satisfies the accept_workers invariant
        for ms in [1u64, 2, 500, 999, 1000, 1001, 30_000] {
            let cfg = SocketCfg::new(Duration::from_millis(ms));
            assert!(
                cfg.heartbeat.is_zero() || cfg.heartbeat < cfg.io_timeout,
                "invariant violated at {ms}ms"
            );
        }
    }

    #[test]
    fn inflight_parses_fixed_and_adaptive() {
        assert_eq!(Inflight::from_str("4"), Ok(Inflight::Fixed(4)));
        assert_eq!(Inflight::from_str("1"), Ok(Inflight::Fixed(1)));
        assert_eq!(
            Inflight::from_str("adaptive"),
            Ok(Inflight::Adaptive)
        );
        assert_eq!(Inflight::from_str("auto"), Ok(Inflight::Adaptive));
        assert!(Inflight::from_str("0").is_err());
        assert!(Inflight::from_str("-1").is_err());
        assert!(Inflight::from_str("fast").is_err());
        assert_eq!(Inflight::Fixed(7).to_string(), "7");
        assert_eq!(Inflight::Adaptive.to_string(), "adaptive");
        assert_eq!(Inflight::Adaptive.initial_window(), 1);
        assert_eq!(Inflight::Fixed(3).exec_threads(), 3);
    }

    #[test]
    fn adaptive_window_grows_and_halves() {
        let window = AtomicUsize::new(1);
        let ewma = AtomicU64::new(0);
        let grown = AtomicU64::new(0);
        // steady latency: additive growth, one slot per window-full
        for _ in 0..200 {
            adapt_window(
                &window,
                &ewma,
                &grown,
                Duration::from_millis(10),
                AIMD_SPIKE_DEFAULT,
                ADAPTIVE_MAX_WINDOW,
            );
        }
        let grown_to = window.load(Ordering::SeqCst);
        assert!(
            grown_to > 1,
            "steady outcomes never grew the window"
        );
        assert!(grown_to <= ADAPTIVE_MAX_WINDOW);
        // a big spike halves it
        adapt_window(
            &window,
            &ewma,
            &grown,
            Duration::from_secs(5),
            AIMD_SPIKE_DEFAULT,
            ADAPTIVE_MAX_WINDOW,
        );
        let after = window.load(Ordering::SeqCst);
        assert_eq!(after, (grown_to / 2).max(1));
        // and the cap holds under unbounded steady traffic
        for _ in 0..10_000 {
            adapt_window(
                &window,
                &ewma,
                &grown,
                Duration::from_millis(10),
                AIMD_SPIKE_DEFAULT,
                ADAPTIVE_MAX_WINDOW,
            );
        }
        assert!(window.load(Ordering::SeqCst) <= ADAPTIVE_MAX_WINDOW);
    }

    /// The satellite-4 regression: AIMD spike/cap are configuration,
    /// not constants. A lower cap bounds growth below the historical
    /// 32, and a larger spike multiplier tolerates latency the
    /// default would halve on.
    #[test]
    fn aimd_spike_and_cap_are_tunable() {
        // cap: steady traffic never grows past a custom bound
        let window = AtomicUsize::new(1);
        let ewma = AtomicU64::new(0);
        let grown = AtomicU64::new(0);
        for _ in 0..10_000 {
            adapt_window(
                &window,
                &ewma,
                &grown,
                Duration::from_millis(10),
                AIMD_SPIKE_DEFAULT,
                3,
            );
        }
        assert_eq!(window.load(Ordering::SeqCst), 3);
        // spike: a 5x latency jump halves under the default (4x)
        // threshold but survives a spike setting of 8
        let seed = |spike: u32| {
            let window = AtomicUsize::new(4);
            let ewma = AtomicU64::new(0);
            let grown = AtomicU64::new(0);
            for _ in 0..50 {
                adapt_window(
                    &window,
                    &ewma,
                    &grown,
                    Duration::from_millis(10),
                    spike,
                    4,
                );
            }
            let before = window.load(Ordering::SeqCst);
            adapt_window(
                &window,
                &ewma,
                &grown,
                Duration::from_millis(50),
                spike,
                4,
            );
            (before, window.load(Ordering::SeqCst))
        };
        let (before, after) = seed(AIMD_SPIKE_DEFAULT);
        assert_eq!(after, (before / 2).max(1), "default spike halves");
        let (before, after) = seed(8);
        assert_eq!(after, before, "a looser spike tolerates the jump");
    }

    /// The satellite-3 regression: hammer acquire/release from many
    /// threads and assert a returned connection is never over its
    /// window. The old double-load pick could exceed it under a
    /// racing free/acquire.
    #[test]
    fn acquire_never_exceeds_window_under_contention() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let window = 2usize;
        let cfg = SocketCfg {
            heartbeat: Duration::ZERO,
            inflight: Inflight::Fixed(window),
            ..SocketCfg::new(Duration::from_secs(5))
        };
        let shared = Arc::new(Shared {
            cfg,
            hello: Hello {
                fingerprint: 1,
                dim: 1,
                model: "hammer".into(),
                auth: 0,
                role: PeerRole::Worker,
                shard: None,
            },
            expect: PeerRole::Worker,
            conns: Mutex::new(Vec::new()),
            slots: Condvar::new(),
            next_conn_id: AtomicU64::new(0),
            next_nonce: AtomicU64::new(0),
            closed: AtomicBool::new(false),
            bytes_sent: AtomicU64::new(0),
            bytes_received: AtomicU64::new(0),
            duplicate_outcomes: AtomicU64::new(0),
            duplicate_outcome_bytes: AtomicU64::new(0),
            heartbeats_sent: AtomicU64::new(0),
            partial_bytes_received: AtomicU64::new(0),
            requeues: AtomicU64::new(0),
            hedges: AtomicU64::new(0),
            hedge_bytes: AtomicU64::new(0),
            threads: Mutex::new(Vec::new()),
        });
        let mut keep = Vec::new(); // client halves keep sockets open
        for id in 0..3u64 {
            keep.push(TcpStream::connect(addr).unwrap());
            let (s, peer) = listener.accept().unwrap();
            let conn = Arc::new(new_conn(
                &shared,
                id,
                peer.to_string(),
                s,
                None,
            ));
            shared.conns.lock().unwrap().push(conn);
        }
        let violations = AtomicU64::new(0);
        thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for _ in 0..2_000 {
                        let c = shared.acquire().unwrap();
                        let load = c.in_flight.load(Ordering::SeqCst);
                        let cap = c.window.load(Ordering::SeqCst);
                        if load > cap {
                            violations.fetch_add(1, Ordering::Relaxed);
                        }
                        shared.release_slot(&c);
                    }
                });
            }
        });
        assert_eq!(
            violations.load(Ordering::Relaxed),
            0,
            "acquire handed out slots past the window"
        );
    }
}
