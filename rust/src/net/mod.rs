//! Networked transport: the wire protocol that lets one federated
//! round physically span processes.
//!
//! Four layers, bottom-up:
//!
//! * [`frame`] — length-prefixed frames with a magic/version header
//!   and CRC-32 checksum; every peer-inducible failure is a typed
//!   [`frame::WireError`].
//! * [`codec`] — message bodies: [`codec::WireJob`] /
//!   [`codec::WireOutcome`] (the serialized forms of
//!   `ClientJob`/`ClientOutcome`) and the [`codec::Hello`] handshake.
//! * [`socket`] — [`socket::SocketTransport`], the TCP-backed
//!   `Transport` the server's round loop drives exactly like the
//!   in-process one.
//! * [`worker`] — the worker-side serve loop wrapping the existing
//!   local executor.
//!
//! Determinism: a networked round is bit-identical to
//! `InProcessTransport` at any parallelism, because the wire moves
//! exactly the bytes the FP8 codec already produces (the encoded
//! broadcast down, the encoded uplink back) and both sides decode
//! them with the same pure functions. Enforced by
//! `tests/net_transport.rs`; the byte layout itself is pinned by
//! `tests/golden_wire.rs` against `tests/fixtures/wire_v1.bin`.

pub mod codec;
pub mod frame;
pub mod socket;
pub mod worker;

pub use codec::{Hello, WireJob, WireOutcome};
pub use frame::{WireError, WIRE_VERSION};
pub use socket::{accept_workers, SocketTransport};
pub use worker::{connect, serve_conn, WorkerCtx};
