//! Networked transport: the wire protocol (v2) that lets one
//! federated round physically span processes.
//!
//! Four layers, bottom-up:
//!
//! * [`frame`] — length-prefixed frames with a magic/version header
//!   and CRC-32 checksum; every peer-inducible failure is a typed
//!   [`frame::WireError`]. v2 adds the Heartbeat/HeartbeatAck kinds
//!   and the resumable [`frame::FrameReader`] the long-lived reader
//!   loops are built on.
//! * [`codec`] — message bodies: [`codec::WireJob`] /
//!   [`codec::WireOutcome`] (v2: tagged with the round-scoped
//!   multiplexing `job_id`), the [`codec::Hello`] handshake and the
//!   heartbeat nonces.
//! * [`poll`] — [`poll::Poller`], the readiness core (epoll shim on
//!   Linux, portable scan fallback elsewhere) that lets one server
//!   thread watch every worker connection plus the listener.
//! * [`socket`] — [`socket::SocketTransport`], the TCP-backed
//!   `Transport` the server's round loop drives exactly like the
//!   in-process one: a single event-driven poll loop owning every
//!   connection, a sliding (optionally adaptive) window of in-flight
//!   jobs per worker, out-of-order completion demultiplexed by job
//!   id, heartbeat liveness, hedged re-dispatch of stragglers, and
//!   failover of un-acked jobs to surviving workers.
//! * [`worker`] — the worker-side serve loop wrapping the existing
//!   local executor: a frame reader feeding an executor pool, plus
//!   the [`worker::OutcomeCache`] that makes reconnects answer
//!   re-dispatched jobs bit-identically without recomputing.
//! * [`aggregator`] — the mid-tier serve loop of the networked tree
//!   (`--role aggregator`): whole cohort shards arrive as
//!   `FrameKind::Shard` work orders, execute through the aggregator's
//!   own downstream transport, and return as a `ShardDone` +
//!   `FrameKind::Partial` pair the root absorbs in cohort order.
//!
//! Determinism: a networked round is bit-identical to
//! `InProcessTransport` at any parallelism, window size, and under
//! any schedule of worker failures that leaves the round completable,
//! because the wire moves exactly the bytes the FP8 codec already
//! produces (the encoded broadcast down, the encoded uplink back),
//! both sides decode them with the same pure functions, and
//! re-execution draws from counter-derived RNG streams. Enforced by
//! `tests/net_transport.rs` and the chaos suite
//! `tests/net_chaos.rs`; the byte layout itself is pinned by
//! `tests/golden_wire.rs` against `tests/fixtures/wire_v2.bin`
//! (v1 frames must fail with the typed version mismatch, pinned
//! against the retained `wire_v1.bin`).

pub mod aggregator;
pub mod codec;
pub mod frame;
pub mod poll;
pub mod socket;
pub mod worker;

pub use aggregator::{serve_upstream, AggregatorCtx};
pub use codec::{
    digest_eq, token_digest, Hello, PeerRole, WireJob, WireOutcome,
};
pub use frame::{FrameReader, WireError, WIRE_VERSION};
pub use poll::Poller;
pub use socket::{
    accept_aggregators, accept_workers, ConnDied, Inflight, SocketCfg,
    SocketTransport,
};
pub use worker::{
    connect, serve_conn, OutcomeCache, ServeOpts, WorkerCtx,
};
