//! Mid-tier aggregator side of the networked tree (`--role
//! aggregator`): the upstream serve loop that turns one
//! [`FrameKind::Shard`] work order per round into a `ShardDone` +
//! [`FrameKind::Partial`] reply pair.
//!
//! An aggregator is a worker whose unit of work is a whole cohort
//! shard: it rebuilds the round context locally (the cohort is a pure
//! function of `(seed, round)`, the broadcast decodes bit-exactly
//! from the shard's packed payload), constructs the same
//! [`ClientJob`]s the root's in-process tree would have built —
//! identical job ids, learning rate, QAT prefix rule and EF residuals
//! — executes them through any [`Transport`] (its own downstream
//! `SocketTransport` pool in the CLI, deterministic mocks in the
//! loopback tests), folds the uplinks into a [`FedAvgStream`] starting
//! at the shard's global cohort offset, and ships the resulting
//! [`TreePartial`] upstream through the real wire codec. Because the
//! stream's pairwise accumulator is canonical over global positions,
//! the root's absorb is bit-identical to the in-process tree and to
//! flat — pinned by tests/tree_net.rs.
//!
//! Liveness mirrors the worker serve loop: the reader keeps servicing
//! the socket (acking root heartbeats) while the executor thread
//! computes the shard, so a busy aggregator is never declared dead;
//! total silence past [`ServeOpts::idle_deadline`] exits with the
//! typed [`WireError::HeartbeatLost`].
//!
//! [`FrameKind::Shard`]: super::frame::FrameKind::Shard
//! [`FrameKind::Partial`]: super::frame::FrameKind::Partial
//! [`WireError::HeartbeatLost`]: super::frame::WireError::HeartbeatLost

use std::collections::{HashMap, VecDeque};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex};
use std::thread;

use anyhow::{bail, ensure, Context, Result};

use crate::config::{ExperimentConfig, QatMode};
use crate::coordinator::aggregate::{
    FedAvgStream, TreePartial, Weighting,
};
use crate::coordinator::cohort::ClientShards;
use crate::coordinator::comm::UPLINK_HEADER_BYTES;
use crate::coordinator::transport::{
    run_cohort, streams, ClientJob, Transport,
};
use crate::coordinator::tree::shard_bounds;
use crate::data::Dataset;
use crate::fp8::codec::{self as fp8codec, DecodeLutCache, Segment};
use crate::fp8::rng::Pcg32;

use super::codec::{self, WireShard, WireShardDone};
use super::frame::{
    self, FrameKind, FrameReader, Liveness, TickAction, WireError,
};
use super::worker::ServeOpts;

/// Everything an aggregator derives locally instead of receiving on
/// the wire — the same pure-function world a worker rebuilds, plus
/// the model geometry its [`FedAvgStream`] needs. Pinned to the
/// root's copy by the handshake fingerprint.
pub struct AggregatorCtx<'a> {
    pub cfg: &'a ExperimentConfig,
    pub train: &'a Dataset,
    pub shards: &'a ClientShards,
    pub segments: &'a [Segment],
    pub dim: usize,
    pub alpha_dim: usize,
    pub beta_dim: usize,
}

/// Queue + shutdown plumbing shared between the upstream reader and
/// the shard executor thread (the aggregator-side mirror of the
/// worker's serve plumbing; shards are strictly heavier than jobs, so
/// one executor thread suffices — downstream parallelism lives in
/// `run_cohort` and the worker pool, not here).
struct UpstreamShared<'a> {
    queue: Mutex<VecDeque<WireShard>>,
    ready: Condvar,
    stop: AtomicBool,
    /// First executor failure; the reader surfaces it.
    failure: Mutex<Option<anyhow::Error>>,
    /// ShardDone + Partial pairs and heartbeat traffic serialize here.
    writer: Mutex<&'a mut TcpStream>,
}

impl UpstreamShared<'_> {
    fn halt(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.ready.notify_all();
    }

    fn fail(&self, e: anyhow::Error) {
        let mut f = self.failure.lock().unwrap();
        if f.is_none() {
            *f = Some(e);
        }
        drop(f);
        self.halt();
    }
}

/// Drop guard: a panicking executor halts the serve loop instead of
/// leaving the reader acking heartbeats for a shard that will never
/// complete (the root cannot tell a wedged aggregator from a slow
/// one, so the aggregator takes itself down).
struct HaltOnPanic<'a, 'b>(&'a UpstreamShared<'b>);

impl Drop for HaltOnPanic<'_, '_> {
    fn drop(&mut self) {
        if thread::panicking() {
            self.0.halt();
        }
    }
}

/// Serve the root connection until it shuts the link down (an
/// explicit Shutdown frame → `Ok`), the connection drops (bare EOF →
/// typed error, so callers reconnect), the idle deadline expires, or
/// a shard fails. Each decoded [`FrameKind::Shard`] is executed on
/// `executor` (the aggregator's downstream transport) and answered
/// with a ShardDone frame immediately followed by the shard's Partial
/// frame on the same connection.
///
/// `opts.exec_threads` is ignored: shard-level concurrency is the
/// root's window, and within a shard `cfg.parallelism` governs the
/// cohort fan-out.
///
/// [`FrameKind::Shard`]: super::frame::FrameKind::Shard
pub fn serve_upstream(
    stream: &mut TcpStream,
    executor: &dyn Transport,
    ctx: &AggregatorCtx<'_>,
    opts: &ServeOpts,
) -> Result<()> {
    ensure!(
        opts.heartbeat.is_zero()
            || opts.idle_deadline.is_zero()
            || opts.heartbeat < opts.idle_deadline,
        "heartbeat interval ({:?}) must be shorter than the idle \
         deadline ({:?}), or zero to disable probing",
        opts.heartbeat,
        opts.idle_deadline
    );
    let live = Liveness::new(opts.heartbeat, opts.idle_deadline);
    let mut reader_stream = stream
        .try_clone()
        .context("cloning the upstream connection for the reader")?;
    reader_stream
        .set_read_timeout(Some(live.tick()))
        .context("setting the upstream read tick")?;
    let shared = UpstreamShared {
        queue: Mutex::new(VecDeque::new()),
        ready: Condvar::new(),
        stop: AtomicBool::new(false),
        failure: Mutex::new(None),
        writer: Mutex::new(stream),
    };
    let result = thread::scope(|s| -> Result<()> {
        {
            let shared = &shared;
            s.spawn(move || {
                let _halt_on_panic = HaltOnPanic(shared);
                shard_executor_loop(shared, executor, ctx);
            });
        }
        let r = reader_loop(&mut reader_stream, &shared, live);
        shared.halt();
        r
    });
    if let Some(e) = shared.failure.lock().unwrap().take() {
        return Err(e);
    }
    result
}

/// The reader side: decode upstream frames, answer heartbeats, queue
/// shards, and run the liveness deadline.
fn reader_loop(
    stream: &mut TcpStream,
    shared: &UpstreamShared<'_>,
    mut live: Liveness,
) -> Result<()> {
    let mut fr = FrameReader::new();
    let mut hb_body = Vec::new();
    let mut nonce = 0u64;
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            // the executor failed; its error is surfaced by
            // serve_upstream
            return Ok(());
        }
        let polled = match fr.poll(stream) {
            Ok(p) => p,
            Err(e) if e.is_clean_close() => {
                return Err(e).context(
                    "upstream connection dropped without a Shutdown \
                     frame",
                );
            }
            Err(e) => {
                return Err(e).context("reading the next upstream frame")
            }
        };
        live.on_progress(fr.bytes_consumed());
        let Some(f) = polled else {
            match live.on_idle(true) {
                TickAction::Dead { idle_ms, deadline_ms } => {
                    return Err(WireError::HeartbeatLost {
                        idle_ms,
                        deadline_ms,
                    })
                    .context("root went silent");
                }
                TickAction::Probe => {
                    nonce = nonce.wrapping_add(1);
                    codec::encode_heartbeat(nonce, &mut hb_body);
                    let mut w = shared.writer.lock().unwrap();
                    frame::write_frame(
                        &mut **w,
                        FrameKind::Heartbeat,
                        &hb_body,
                    )
                    .context("probing the root")?;
                }
                TickAction::Idle => {}
            }
            continue;
        };
        match f.kind {
            FrameKind::Shutdown => return Ok(()),
            FrameKind::Heartbeat => {
                let n = codec::decode_heartbeat(&f.body)?;
                codec::encode_heartbeat(n, &mut hb_body);
                let mut w = shared.writer.lock().unwrap();
                frame::write_frame(
                    &mut **w,
                    FrameKind::HeartbeatAck,
                    &hb_body,
                )
                .context("acking a root heartbeat")?;
            }
            FrameKind::HeartbeatAck => {
                codec::decode_heartbeat(&f.body)?;
            }
            FrameKind::Shard => {
                let shard = codec::decode_shard(&f.body)
                    .context("decoding shard frame")?;
                let mut q = shared.queue.lock().unwrap();
                q.push_back(shard);
                drop(q);
                shared.ready.notify_one();
            }
            k => bail!(
                "unexpected {k:?} frame on the aggregator's upstream \
                 link"
            ),
        }
    }
}

/// The executor thread: drain the shard queue, run each shard's
/// sub-round, reply ShardDone then Partial.
fn shard_executor_loop(
    shared: &UpstreamShared<'_>,
    executor: &dyn Transport,
    ctx: &AggregatorCtx<'_>,
) {
    let mut lut = DecodeLutCache::default();
    let mut w_start: Vec<f32> = Vec::new();
    let mut done_body = Vec::new();
    let mut partial_body = Vec::new();
    loop {
        let shard = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(s) = q.pop_front() {
                    break Some(s);
                }
                if shared.stop.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared.ready.wait(q).unwrap();
            }
        };
        let Some(shard) = shard else { return };
        // ids survive the move of `shard` into run_shard (context)
        let (round, lo, hi) = (shard.round, shard.lo, shard.hi);
        match run_shard(shard, executor, ctx, &mut lut, &mut w_start) {
            Ok((done, partial)) => {
                codec::encode_shard_done(&done, &mut done_body);
                codec::encode_partial(round, &partial, &mut partial_body);
                // ShardDone strictly precedes the Partial on the wire
                // (the root treats the reverse order as malformed):
                // one writer lock spans the pair
                let mut w = shared.writer.lock().unwrap();
                let r = frame::write_frame(
                    &mut **w,
                    FrameKind::ShardDone,
                    &done_body,
                )
                .and_then(|()| {
                    frame::write_frame(
                        &mut **w,
                        FrameKind::Partial,
                        &partial_body,
                    )
                });
                if let Err(e) = r {
                    drop(w);
                    shared.fail(anyhow::Error::from(e).context(
                        format!(
                            "returning shard [{lo}, {hi}) round {round}"
                        ),
                    ));
                    return;
                }
            }
            Err(e) => {
                shared.fail(e.context(format!(
                    "executing shard [{lo}, {hi}) round {round}"
                )));
                return;
            }
        }
    }
}

/// Rebuild the round context and execute one shard: the networked
/// twin of the shard body of `tree::run_tree`, constructing jobs
/// exactly as `Server::round` does so every byte downstream — and the
/// folded partial upstream — is bit-identical to the in-process tree.
fn run_shard(
    shard: WireShard,
    executor: &dyn Transport,
    ctx: &AggregatorCtx<'_>,
    lut: &mut DecodeLutCache,
    w_start: &mut Vec<f32>,
) -> Result<(WireShardDone, TreePartial)> {
    let cfg = ctx.cfg;
    let t = shard.round as usize;
    // the cohort is a pure function of (seed, round) — only the
    // position range travelled
    let participants =
        Pcg32::derive(cfg.seed, t as u64, 0, streams::COHORT)
            .sample_distinct_sparse(
                ctx.shards.n_clients(),
                cfg.participation,
            );
    let (lo, hi) = (shard.lo as usize, shard.hi as usize);
    // the locally derived geometry must agree with the root's, or the
    // worlds diverged despite matching fingerprints
    let expect = shard_bounds(participants.len(), shard.nodes as usize)
        .get(shard.index as usize)
        .copied();
    ensure!(
        expect == Some((lo, hi)),
        "shard {}/{} claims positions [{lo}, {hi}), local round-{t} \
         geometry says {expect:?} — worlds diverged",
        shard.index,
        shard.nodes,
    );
    // hard reset: decode the broadcast exactly as the root did (a
    // pure LUT function of the payload bytes)
    fp8codec::decode_into_pooled(
        &shard.down,
        ctx.segments,
        lut,
        cfg.parallelism,
        w_start,
    );
    let w_start: &[f32] = w_start;
    let lr = cfg.schedule.lr_at(cfg.lr, t, cfg.rounds);
    // m_t spans the FULL cohort (weights are global, not per-shard)
    let m_t: u64 = participants
        .iter()
        .map(|&k| ctx.shards.n_k(k))
        .sum();
    let weighting = Weighting::for_cohort(m_t, participants.len());
    let n_clients = ctx.shards.n_clients();
    let mut efs: HashMap<u32, Vec<f32>> =
        shard.efs.into_iter().collect();
    let members = &participants[lo..hi];
    let cohort_shards: Vec<_> =
        members.iter().map(|&k| ctx.shards.shard(k)).collect();
    let mut jobs = Vec::with_capacity(members.len());
    for (rel, &k) in members.iter().enumerate() {
        // the same FP32-prefix heterogeneity rule as Server::round
        let qat = if (k as f32)
            < cfg.fp32_client_frac * n_clients as f32
        {
            QatMode::None
        } else {
            cfg.qat
        };
        // under EF the root ships every member's residual (zeros
        // included); the fallback covers nothing in practice but
        // keeps a missing entry from being a panic
        let ef = if cfg.error_feedback {
            Some(
                efs.remove(&(k as u32))
                    .unwrap_or_else(|| vec![0.0f32; ctx.dim]),
            )
        } else {
            None
        };
        jobs.push(ClientJob {
            round: t,
            client: k,
            // the dispatch tag is the GLOBAL cohort position
            job_id: (lo + rel) as u32,
            seed: cfg.seed,
            qat,
            lr,
            weight_decay: cfg.weight_decay,
            flip_aug: cfg.flip_aug,
            comm: cfg.comm,
            w_start,
            alpha_start: &shard.down.alphas,
            beta_start: &shard.down.betas,
            train: ctx.train,
            shard: cohort_shards[rel].as_ref(),
            segments: ctx.segments,
            n_k: cohort_shards[rel].len() as u64,
            ef,
            down: &shard.down,
        });
    }
    // the mid stream starts at the shard's global offset, so its
    // partial slots into the root's canonical accumulator
    let mut mid = FedAvgStream::with_weighting(
        ctx.segments,
        ctx.dim,
        ctx.alpha_dim,
        ctx.beta_dim,
        weighting,
        false,
        shard.lo,
    )?;
    let mut up_bytes = 0u64;
    let mut up_msgs = 0u64;
    let mut ret_efs: Vec<(u32, Vec<f32>)> = Vec::new();
    run_cohort(
        executor,
        jobs,
        cfg.parallelism,
        cfg.fp8_kernel,
        |_rel, mut out| {
            // client-edge accounting, mirroring CommStats::record_up
            // charge for charge — summed here, added raw at the root
            up_bytes +=
                out.uplink.payload.wire_bytes() + UPLINK_HEADER_BYTES;
            up_msgs += 1;
            // EVERY residual returns, all-zero ones included — the
            // root's store_ef eviction depends on seeing them
            if let Some(e) = out.ef.take() {
                ret_efs.push((out.uplink.client as u32, e));
            }
            mid.push(&out.uplink);
            Ok(())
        },
    )?;
    ret_efs.sort_unstable_by_key(|&(c, _)| c);
    let partial = mid.into_partial()?;
    Ok((
        WireShardDone {
            round: shard.round,
            lo: shard.lo,
            hi: shard.hi,
            up_bytes,
            up_msgs,
            efs: ret_efs,
        },
        partial,
    ))
}
