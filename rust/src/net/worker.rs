//! Worker side of the v2 networked transport: handshake, world
//! reconstruction context, the multiplexed serve loop, and the
//! reconnect-safe outcome cache.
//!
//! A worker is a shell around the *existing* local executor: it
//! decodes a [`WireJob`] into a regular [`ClientJob`] (rebuilding
//! `w_start` bit-exactly by decoding the FP8 broadcast it received),
//! hands it to any [`Transport`] implementation — the real
//! `InProcessTransport` in the CLI driver, deterministic mocks in the
//! loopback tests — and streams the outcome back. Because the uplink
//! is packed by the same `finish_uplink` path with the same
//! counter-derived RNG streams, a worker's bytes are identical to
//! what the in-process simulation would have produced.
//!
//! ## v2: multiplexing, heartbeats, reconnect cache
//!
//! The serve loop no longer runs one job at a time. A dedicated
//! reader (the calling thread) decodes incoming frames and feeds a
//! job queue drained by `exec_threads` scoped executor threads, so
//! the connection accepts the server's whole in-flight window while
//! earlier jobs still compute, and outcomes return **out of order**
//! (the server demultiplexes them by `(round, client, job_id)`).
//! Because the reader keeps servicing the socket during computation,
//! heartbeat probes are answered promptly even under load.
//!
//! Liveness: when the connection has been silent for
//! [`ServeOpts::heartbeat`], the worker probes the server; if nothing
//! at all arrives for [`ServeOpts::idle_deadline`], the loop exits
//! with the typed [`WireError::HeartbeatLost`] — a silent partition
//! is detected instead of waiting forever.
//!
//! Reconnect safety: every finished outcome body is stored in the
//! [`OutcomeCache`] under `(fingerprint, round, client, job_id,
//! job-body crc)`. When a connection drops and the job is dispatched
//! again — to this worker over a fresh connection, or duplicated by a
//! flaky network — the cached bytes are returned verbatim: the reply
//! is bit-identical by construction and costs no recomputation. (Even
//! on a cache miss re-execution is bit-identical, because all client
//! randomness is counter-derived; the cache only saves the work.)
//!
//! [`WireJob`]: super::codec::WireJob
//! [`WireError::HeartbeatLost`]: super::frame::WireError::HeartbeatLost

use std::collections::{HashMap, VecDeque};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::cohort::ClientShards;
use crate::coordinator::transport::{ClientJob, Transport, WorkBuffers};
use crate::data::Dataset;
use crate::fp8::codec::{self as fp8codec, DecodeLutCache, Segment};
use crate::fp8::simd::KernelKind;

use super::codec::{self, Hello, WireJob, WireOutcome};
use super::frame::{
    self, FrameKind, FrameReader, Liveness, TickAction, WireError,
};

/// Everything a worker derives locally instead of receiving on the
/// wire: the synthetic dataset, the client shards and the model's
/// segment table — all pure functions of (config, manifest), rebuilt
/// by `coordinator::server::build_world` and pinned to the server's
/// copy by the handshake fingerprint.
pub struct WorkerCtx<'a> {
    pub train: &'a Dataset,
    pub shards: &'a ClientShards,
    pub segments: &'a [Segment],
    /// This worker's uplink quantize/encode kernel (from its own
    /// config copy; bit-identical across kernels, so workers and
    /// server may pin different ones).
    pub kernel: KernelKind,
}

/// Serve-loop tuning (the worker-side mirror of the server's
/// `SocketCfg`).
#[derive(Clone, Copy, Debug)]
pub struct ServeOpts {
    /// Probe the server after this much connection silence;
    /// `Duration::ZERO` disables worker-initiated heartbeats.
    pub heartbeat: Duration,
    /// Declare the server dead after this much total silence;
    /// `Duration::ZERO` disables the deadline (v1 behaviour: wait for
    /// work forever). Only meaningful with heartbeats on — without
    /// probes an idle-but-healthy server legitimately sends nothing.
    pub idle_deadline: Duration,
    /// Executor threads draining the job queue — how much of the
    /// server's in-flight window this worker computes concurrently.
    pub exec_threads: usize,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        let idle_deadline = Duration::from_secs(30);
        ServeOpts {
            // derived (min(1 s, deadline/4)) like the server side, so
            // the probe-before-deadline invariant holds for any
            // deadline override
            heartbeat: Liveness::default_heartbeat(idle_deadline),
            idle_deadline,
            exec_threads: 1,
        }
    }
}

/// Key of one cached outcome: `(config fingerprint, round, client,
/// job_id, crc32 of the job body)`. The crc term makes the cache
/// self-guarding — two jobs can only collide on the full key if their
/// bytes were identical, in which case the cached reply is exactly
/// right.
pub type CacheKey = (u64, u32, u32, u32, u32);

struct CacheInner {
    cap: usize,
    map: HashMap<CacheKey, Vec<u8>>,
    /// LRU order, least-recent first (small caps: O(cap) touch is
    /// cheaper than a linked structure).
    order: VecDeque<CacheKey>,
}

/// LRU cache of encoded outcome bodies, shared by every connection a
/// worker process serves — the state that makes reconnects cheap.
pub struct OutcomeCache {
    inner: Mutex<CacheInner>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl OutcomeCache {
    /// `cap` = retained outcomes (>= the server's in-flight window,
    /// ideally a round's cohort share); 0 disables caching.
    pub fn new(cap: usize) -> OutcomeCache {
        OutcomeCache {
            inner: Mutex::new(CacheInner {
                cap,
                map: HashMap::new(),
                order: VecDeque::new(),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Cached outcome body for `key`, refreshing its recency.
    pub fn get(&self, key: &CacheKey) -> Option<Vec<u8>> {
        let mut c = self.inner.lock().unwrap();
        let hit = c.map.get(key).cloned();
        match hit {
            Some(bytes) => {
                if let Some(i) = c.order.iter().position(|k| k == key) {
                    c.order.remove(i);
                    c.order.push_back(*key);
                }
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(bytes)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert an outcome body, evicting the least-recently-used entry
    /// past capacity.
    pub fn put(&self, key: CacheKey, bytes: Vec<u8>) {
        let mut c = self.inner.lock().unwrap();
        if c.cap == 0 {
            return;
        }
        if c.map.insert(key, bytes).is_none() {
            c.order.push_back(key);
        }
        while c.map.len() > c.cap {
            let Some(old) = c.order.pop_front() else { break };
            c.map.remove(&old);
        }
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (hits, misses) counters — observability for the chaos suite.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }
}

/// Connect to a server, perform the Hello/HelloAck handshake and
/// return the stream ready for [`serve_conn`]. `timeout` bounds the
/// handshake only; the serve loop installs its own read tick.
pub fn connect(
    addr: &str,
    hello: &Hello,
    timeout: Duration,
) -> Result<TcpStream> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to server {addr}"))?;
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(timeout))
        .context("setting handshake timeout")?;
    let mut body = Vec::new();
    codec::encode_hello(hello, &mut body);
    frame::write_frame(&mut stream, FrameKind::Hello, &body)
        .context("sending Hello")?;
    let f = frame::read_frame(&mut stream)
        .context("awaiting HelloAck (did the server reject the \
                  handshake? check its log)")?;
    ensure!(
        f.kind == FrameKind::HelloAck,
        "expected HelloAck, server sent {:?}",
        f.kind
    );
    let (fp, auth) = codec::decode_hello_ack(&f.body)?;
    ensure!(
        fp == hello.fingerprint,
        "server acked fingerprint {fp:#018x}, ours is {:#018x}",
        hello.fingerprint
    );
    // mutual auth: a worker must not serve a foreign coordinator
    // either (the server proved itself by echoing our digest)
    if !codec::digest_eq(auth, hello.auth) {
        return Err(WireError::AuthRejected)
            .context("verifying the server's HelloAck auth digest");
    }
    Ok(stream)
}

/// One queued unit of work: the decoded job plus its cache key.
struct QueuedJob {
    wire: WireJob,
    key: CacheKey,
}

/// Queue + shutdown plumbing shared between the reader and the
/// executor pool.
struct ServeShared<'a> {
    queue: Mutex<VecDeque<QueuedJob>>,
    ready: Condvar,
    stop: AtomicBool,
    /// First executor failure; the reader surfaces it.
    failure: Mutex<Option<anyhow::Error>>,
    /// All outcome writes (executors + cached replies) serialize here.
    writer: Mutex<&'a mut TcpStream>,
}

impl ServeShared<'_> {
    fn halt(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.ready.notify_all();
    }

    fn fail(&self, e: anyhow::Error) {
        let mut f = self.failure.lock().unwrap();
        if f.is_none() {
            *f = Some(e);
        }
        drop(f);
        self.halt();
    }
}

/// Drop guard around each executor thread: a panicking executor halts
/// the serve loop (so the reader stops answering heartbeats and the
/// panic propagates at scope join) instead of leaving the connection
/// "alive" with a job that will never complete.
struct HaltOnPanic<'a, 'b>(&'a ServeShared<'b>);

impl Drop for HaltOnPanic<'_, '_> {
    fn drop(&mut self) {
        if thread::panicking() {
            self.0.halt();
        }
    }
}

/// Serve one connection until the server shuts it down (an explicit
/// Shutdown frame → `Ok`), the connection drops (bare EOF → typed
/// error, so callers reconnect), the idle deadline expires, or an
/// executor fails. Decoded jobs run on `executor` across
/// [`ServeOpts::exec_threads`] threads; outcomes stream back on the
/// same connection as they finish (out of order is fine — v2 frames
/// carry the demultiplexing `job_id`). `fingerprint` scopes the
/// `cache` keys to this experiment config.
pub fn serve_conn(
    stream: &mut TcpStream,
    executor: &dyn Transport,
    ctx: &WorkerCtx<'_>,
    opts: &ServeOpts,
    fingerprint: u64,
    cache: &OutcomeCache,
) -> Result<()> {
    let exec_threads = opts.exec_threads.max(1);
    // probe-before-deadline invariant (mirror of accept_workers):
    // the server must have been probed before we give up on it
    ensure!(
        opts.heartbeat.is_zero()
            || opts.idle_deadline.is_zero()
            || opts.heartbeat < opts.idle_deadline,
        "heartbeat interval ({:?}) must be shorter than the idle \
         deadline ({:?}), or zero to disable probing",
        opts.heartbeat,
        opts.idle_deadline
    );
    // the read tick must be short enough to run the heartbeat state
    // machine; Liveness caps it so join latency stays bounded too
    let live = Liveness::new(opts.heartbeat, opts.idle_deadline);
    let mut reader_stream = stream
        .try_clone()
        .context("cloning the connection for the serve reader")?;
    reader_stream
        .set_read_timeout(Some(live.tick()))
        .context("setting the serve read tick")?;
    let shared = ServeShared {
        queue: Mutex::new(VecDeque::new()),
        ready: Condvar::new(),
        stop: AtomicBool::new(false),
        failure: Mutex::new(None),
        writer: Mutex::new(stream),
    };

    let result = thread::scope(|s| -> Result<()> {
        for _ in 0..exec_threads {
            let shared = &shared;
            s.spawn(move || {
                // an executor that PANICS (rather than returning an
                // error) must still unwedge the reader: otherwise the
                // reader would keep acking the server's heartbeats
                // forever while the job never completes — the server
                // cannot tell a wedged worker from a slow one, so the
                // worker has to take itself down
                let _halt_on_panic = HaltOnPanic(shared);
                executor_loop(shared, executor, ctx, cache);
            });
        }
        let r = reader_loop(
            &mut reader_stream,
            &shared,
            live,
            ctx,
            fingerprint,
            cache,
        );
        // stop executors no matter how the reader exited; the scope
        // joins them before the borrows end
        shared.halt();
        r
    });
    // an executor failure is the more actionable error
    if let Some(e) = shared.failure.lock().unwrap().take() {
        return Err(e);
    }
    result
}

/// The reader side of the serve loop: decode frames, answer
/// heartbeats, serve cached outcomes, queue fresh jobs, and run the
/// liveness deadline.
fn reader_loop(
    stream: &mut TcpStream,
    shared: &ServeShared<'_>,
    mut live: Liveness,
    ctx: &WorkerCtx<'_>,
    fingerprint: u64,
    cache: &OutcomeCache,
) -> Result<()> {
    let mut fr = FrameReader::new();
    let mut hb_body = Vec::new();
    let mut nonce = 0u64;
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            // an executor failed; its error is surfaced by serve_conn
            return Ok(());
        }
        let polled = match fr.poll(stream) {
            Ok(p) => p,
            Err(e) if e.is_clean_close() => {
                // v2: orderly shutdown is an explicit Shutdown frame;
                // a bare EOF is a dropped connection, which callers
                // (the CLI reconnect loop, the chaos workers) answer
                // by reconnecting with the outcome cache intact
                return Err(e).context(
                    "connection dropped without a Shutdown frame",
                );
            }
            Err(e) => return Err(e).context("reading the next frame"),
        };
        // any stream progress (even a partial frame) proves liveness
        live.on_progress(fr.bytes_consumed());
        let Some(f) = polled else {
            // idle tick: probe, then give up past the deadline
            match live.on_idle(true) {
                TickAction::Dead { idle_ms, deadline_ms } => {
                    return Err(WireError::HeartbeatLost {
                        idle_ms,
                        deadline_ms,
                    })
                    .context("server went silent");
                }
                TickAction::Probe => {
                    nonce = nonce.wrapping_add(1);
                    codec::encode_heartbeat(nonce, &mut hb_body);
                    let mut w = shared.writer.lock().unwrap();
                    frame::write_frame(
                        &mut **w,
                        FrameKind::Heartbeat,
                        &hb_body,
                    )
                    .context("probing the server")?;
                }
                TickAction::Idle => {}
            }
            continue;
        };
        match f.kind {
            FrameKind::Shutdown => return Ok(()),
            FrameKind::Heartbeat => {
                let n = codec::decode_heartbeat(&f.body)?;
                codec::encode_heartbeat(n, &mut hb_body);
                let mut w = shared.writer.lock().unwrap();
                frame::write_frame(
                    &mut **w,
                    FrameKind::HeartbeatAck,
                    &hb_body,
                )
                .context("acking a server heartbeat")?;
            }
            FrameKind::HeartbeatAck => {
                // liveness already refreshed above
                codec::decode_heartbeat(&f.body)?;
            }
            FrameKind::Job => {
                let wire = codec::decode_job(&f.body)
                    .context("decoding job frame")?;
                validate_job(&wire, ctx)?;
                let key: CacheKey = (
                    fingerprint,
                    wire.round,
                    wire.client,
                    wire.job_id,
                    frame::crc32(&f.body),
                );
                if let Some(bytes) = cache.get(&key) {
                    // re-dispatch after a drop (or a duplicated job):
                    // reply with the cached bit-identical outcome
                    let mut w = shared.writer.lock().unwrap();
                    frame::write_frame(
                        &mut **w,
                        FrameKind::Outcome,
                        &bytes,
                    )
                    .with_context(|| {
                        format!(
                            "returning cached outcome for client {}",
                            wire.client
                        )
                    })?;
                } else {
                    let mut q = shared.queue.lock().unwrap();
                    q.push_back(QueuedJob { wire, key });
                    drop(q);
                    shared.ready.notify_one();
                }
            }
            k => bail!("unexpected {k:?} frame in the serve loop"),
        }
    }
}

/// Sanity-check a decoded job against the locally rebuilt world.
fn validate_job(wire: &WireJob, ctx: &WorkerCtx<'_>) -> Result<()> {
    let client = wire.client as usize;
    ensure!(
        client < ctx.shards.n_clients(),
        "job for client {client}, but this world has only {} \
         clients — configs out of sync despite matching fingerprints?",
        ctx.shards.n_clients()
    );
    ensure!(
        wire.n_k == ctx.shards.n_k(client),
        "job for client {client} says n_k = {}, local shard has {} \
         samples — worlds diverged",
        wire.n_k,
        ctx.shards.n_k(client)
    );
    Ok(())
}

/// One executor thread: drain the queue, run the local update, encode
/// + cache + send the outcome.
fn executor_loop(
    shared: &ServeShared<'_>,
    executor: &dyn Transport,
    ctx: &WorkerCtx<'_>,
    cache: &OutcomeCache,
) {
    let mut buffers = WorkBuffers::with_kernel(ctx.kernel);
    let mut lut = DecodeLutCache::default();
    let mut w_start: Vec<f32> = Vec::new();
    let mut out_body = Vec::new();
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if shared.stop.load(Ordering::SeqCst) {
                    break None;
                }
                q = shared.ready.wait(q).unwrap();
            }
        };
        let Some(QueuedJob { wire, key }) = job else { return };
        // ids survive the move of `wire` into run_one (error context)
        let (client, round) = (wire.client, wire.round);
        match run_one(
            wire, executor, ctx, &mut buffers, &mut lut, &mut w_start,
        ) {
            Ok(out) => {
                codec::encode_outcome(&out, &mut out_body);
                cache.put(key, out_body.clone());
                let mut w = shared.writer.lock().unwrap();
                if let Err(e) = frame::write_frame(
                    &mut **w,
                    FrameKind::Outcome,
                    &out_body,
                ) {
                    drop(w);
                    shared.fail(anyhow::Error::from(e).context(
                        format!(
                            "returning outcome for client {client}"
                        ),
                    ));
                    return;
                }
            }
            Err(e) => {
                shared.fail(e.context(format!(
                    "executing client {client} round {round}"
                )));
                return;
            }
        }
    }
}

/// Decode the broadcast and run one client job on the local executor.
/// Takes the [`WireJob`] by value so the error-feedback residual is
/// *moved* into the job, not cloned (a model-dimension Vec per job).
fn run_one(
    wire: WireJob,
    executor: &dyn Transport,
    ctx: &WorkerCtx<'_>,
    buffers: &mut WorkBuffers,
    lut: &mut DecodeLutCache,
    w_start: &mut Vec<f32>,
) -> Result<WireOutcome> {
    let client = wire.client as usize;
    let round = wire.round as usize;
    // hard reset: decode the broadcast exactly as the server did
    // (decode is a pure LUT function of the payload bytes, so this
    // w_start is bit-identical to the server's)
    fp8codec::decode_into_pooled(
        &wire.down,
        ctx.segments,
        lut,
        1,
        w_start,
    );
    // materialized on demand under a virtualized population (Cow is
    // a borrow for dense shards — no copy on the common path)
    let shard = ctx.shards.shard(client);
    let job = ClientJob {
        round,
        client,
        job_id: wire.job_id,
        seed: wire.seed,
        qat: wire.qat,
        lr: wire.lr,
        weight_decay: wire.weight_decay,
        flip_aug: wire.flip_aug,
        comm: wire.comm,
        w_start,
        alpha_start: &wire.down.alphas,
        beta_start: &wire.down.betas,
        train: ctx.train,
        shard: shard.as_ref(),
        segments: ctx.segments,
        n_k: wire.n_k,
        ef: wire.ef,
        down: &wire.down,
    };
    let out = executor.run_client(job, buffers)?;
    Ok(WireOutcome {
        round: wire.round,
        client: wire.client,
        job_id: wire.job_id,
        n_k: out.uplink.n_k,
        mean_loss: out.uplink.mean_loss,
        payload: out.uplink.payload,
        ef: out.ef,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outcome_cache_is_lru_with_hit_stats() {
        let c = OutcomeCache::new(2);
        let k = |i: u32| (7u64, 0u32, i, i, 0u32);
        c.put(k(1), vec![1]);
        c.put(k(2), vec![2]);
        assert_eq!(c.get(&k(1)), Some(vec![1])); // 1 now most recent
        c.put(k(3), vec![3]); // evicts 2
        assert_eq!(c.get(&k(2)), None);
        assert_eq!(c.get(&k(1)), Some(vec![1]));
        assert_eq!(c.get(&k(3)), Some(vec![3]));
        assert_eq!(c.len(), 2);
        let (hits, misses) = c.stats();
        assert_eq!((hits, misses), (3, 1));
        // re-putting an existing key must not duplicate its LRU slot
        c.put(k(1), vec![9]);
        c.put(k(4), vec![4]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.get(&k(1)), Some(vec![9]));
    }

    #[test]
    fn zero_capacity_cache_stores_nothing() {
        let c = OutcomeCache::new(0);
        c.put((0, 0, 0, 0, 0), vec![1]);
        assert!(c.is_empty());
        assert_eq!(c.get(&(0, 0, 0, 0, 0)), None);
    }
}
