//! Worker side of the networked transport: handshake, world
//! reconstruction context, and the blocking serve loop.
//!
//! A worker is a thin shell around the *existing* local executor: it
//! decodes a [`WireJob`] into a regular [`ClientJob`] (rebuilding
//! `w_start` bit-exactly by decoding the FP8 broadcast it received),
//! hands it to any [`Transport`] implementation — the real
//! `InProcessTransport` in the CLI driver, deterministic mocks in the
//! loopback tests — and streams the outcome back. Because the uplink
//! is packed by the same `finish_uplink` path with the same
//! counter-derived RNG streams, a worker's bytes are identical to
//! what the in-process simulation would have produced.
//!
//! [`WireJob`]: super::codec::WireJob

use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, ensure, Context, Result};

use crate::data::Dataset;
use crate::fp8::codec::{self as fp8codec, DecodeLutCache, Segment};
use crate::fp8::simd::KernelKind;
use crate::coordinator::transport::{ClientJob, Transport, WorkBuffers};

use super::codec::{self, Hello, WireOutcome};
use super::frame::{self, FrameKind};

/// Everything a worker derives locally instead of receiving on the
/// wire: the synthetic dataset, the client shards and the model's
/// segment table — all pure functions of (config, manifest), rebuilt
/// by `coordinator::server::build_world` and pinned to the server's
/// copy by the handshake fingerprint.
pub struct WorkerCtx<'a> {
    pub train: &'a Dataset,
    pub shards: &'a [Vec<usize>],
    pub segments: &'a [Segment],
    /// This worker's uplink quantize/encode kernel (from its own
    /// config copy; bit-identical across kernels, so workers and
    /// server may pin different ones).
    pub kernel: KernelKind,
}

/// Connect to a server, perform the Hello/HelloAck handshake and
/// return the stream ready for [`serve_conn`]. `timeout` bounds the
/// handshake only; the serve loop then blocks indefinitely waiting
/// for work (idle gaps between rounds are normal).
pub fn connect(
    addr: &str,
    hello: &Hello,
    timeout: Duration,
) -> Result<TcpStream> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to server {addr}"))?;
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(timeout))
        .context("setting handshake timeout")?;
    let mut body = Vec::new();
    codec::encode_hello(hello, &mut body);
    frame::write_frame(&mut stream, FrameKind::Hello, &body)
        .context("sending Hello")?;
    let f = frame::read_frame(&mut stream)
        .context("awaiting HelloAck (did the server reject the \
                  handshake? check its log)")?;
    ensure!(
        f.kind == FrameKind::HelloAck,
        "expected HelloAck, server sent {:?}",
        f.kind
    );
    let fp = codec::decode_hello_ack(&f.body)?;
    ensure!(
        fp == hello.fingerprint,
        "server acked fingerprint {fp:#018x}, ours is {:#018x}",
        hello.fingerprint
    );
    // the serve loop waits for work without a deadline
    stream
        .set_read_timeout(None)
        .context("clearing handshake timeout")?;
    Ok(stream)
}

/// Serve one connection until the server shuts it down (Shutdown
/// frame or a clean close between frames). Every decoded job runs on
/// `executor`; outcomes stream back on the same connection.
pub fn serve_conn(
    stream: &mut TcpStream,
    executor: &dyn Transport,
    ctx: &WorkerCtx<'_>,
) -> Result<()> {
    let mut buffers = WorkBuffers::with_kernel(ctx.kernel);
    let mut lut = DecodeLutCache::default();
    let mut w_start: Vec<f32> = Vec::new();
    let mut out_body = Vec::new();
    loop {
        let f = match frame::read_frame(stream) {
            Ok(f) => f,
            Err(e) if e.is_clean_close() => return Ok(()),
            Err(e) => {
                return Err(e).context("reading next job frame")
            }
        };
        match f.kind {
            FrameKind::Shutdown => return Ok(()),
            FrameKind::Job => {}
            k => bail!("unexpected {k:?} frame in the serve loop"),
        }
        let wire = codec::decode_job(&f.body)
            .context("decoding job frame")?;
        let client = wire.client as usize;
        let round = wire.round as usize;
        ensure!(
            client < ctx.shards.len(),
            "job for client {client}, but this world has only {} \
             clients — configs out of sync despite matching \
             fingerprints?",
            ctx.shards.len()
        );
        let shard = &ctx.shards[client];
        ensure!(
            wire.n_k == shard.len() as u64,
            "job for client {client} says n_k = {}, local shard has \
             {} samples — worlds diverged",
            wire.n_k,
            shard.len()
        );
        // hard reset: decode the broadcast exactly as the server did
        // (decode is a pure LUT function of the payload bytes, so
        // this w_start is bit-identical to the server's)
        fp8codec::decode_into_pooled(
            &wire.down,
            ctx.segments,
            &mut lut,
            1,
            &mut w_start,
        );
        let job = ClientJob {
            round,
            client,
            seed: wire.seed,
            qat: wire.qat,
            lr: wire.lr,
            weight_decay: wire.weight_decay,
            flip_aug: wire.flip_aug,
            comm: wire.comm,
            w_start: &w_start,
            alpha_start: &wire.down.alphas,
            beta_start: &wire.down.betas,
            train: ctx.train,
            shard,
            segments: ctx.segments,
            n_k: wire.n_k,
            ef: wire.ef,
            down: &wire.down,
        };
        let out = executor.run_client(job, &mut buffers).with_context(
            || format!("executing client {client} round {round}"),
        )?;
        let wire_out = WireOutcome {
            round: round as u32,
            client: client as u32,
            n_k: out.uplink.n_k,
            mean_loss: out.uplink.mean_loss,
            payload: out.uplink.payload,
            ef: out.ef,
        };
        codec::encode_outcome(&wire_out, &mut out_body);
        frame::write_frame(stream, FrameKind::Outcome, &out_body)
            .with_context(|| {
                format!("returning outcome for client {client}")
            })?;
    }
}
