//! Readiness polling for the event-driven server transport.
//!
//! [`Poller`] wraps the smallest useful slice of `epoll(7)` — add /
//! delete / wait on level-triggered read-readiness — behind a
//! token-based API, so `net/socket.rs` can drive every worker
//! connection (and the listener) from **one** thread instead of a
//! reader thread per connection.
//!
//! On Linux this is a direct FFI shim over the libc symbols already
//! linked by `std` (the crate deliberately has no `libc` dependency).
//! On other platforms a portable scan fallback reports *every*
//! registered token as ready on a short cadence; combined with
//! non-blocking sockets (reads return `WouldBlock`, which
//! `FrameReader::poll` maps to "no frame yet") that is slower but
//! exactly as correct — the poll loop is written to treat readiness
//! as a hint, never a guarantee.
//!
//! Tokens are plain `u64`s owned by the caller. Level-triggered
//! semantics: a socket with unread bytes is reported on every `wait`
//! until drained, so a caller capping its per-wakeup work never loses
//! data.

use std::io;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

#[cfg(target_os = "linux")]
mod sys {
    use super::*;
    use std::os::unix::io::RawFd;

    // Mirrors glibc's `struct epoll_event`, which is `__EPOLL_PACKED`
    // (packed) on x86_64 and naturally aligned elsewhere.
    #[cfg(target_arch = "x86_64")]
    #[repr(C, packed)]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }
    #[cfg(not(target_arch = "x86_64"))]
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLLIN: u32 = 0x1;
    const EPOLLRDHUP: u32 = 0x2000;

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(
            epfd: i32,
            op: i32,
            fd: i32,
            event: *mut EpollEvent,
        ) -> i32;
        fn epoll_wait(
            epfd: i32,
            events: *mut EpollEvent,
            maxevents: i32,
            timeout_ms: i32,
        ) -> i32;
        fn close(fd: i32) -> i32;
    }

    fn cvt(ret: i32) -> io::Result<()> {
        if ret < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(())
        }
    }

    pub struct Impl {
        epfd: RawFd,
    }

    impl Impl {
        pub fn new() -> io::Result<Impl> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Impl { epfd })
        }

        pub fn add(&mut self, fd: RawFd, token: u64) -> io::Result<()> {
            let mut ev = EpollEvent {
                events: EPOLLIN | EPOLLRDHUP,
                data: token,
            };
            cvt(unsafe {
                epoll_ctl(self.epfd, EPOLL_CTL_ADD, fd, &mut ev)
            })
        }

        pub fn del(&mut self, fd: RawFd, _token: u64) -> io::Result<()> {
            cvt(unsafe {
                epoll_ctl(
                    self.epfd,
                    EPOLL_CTL_DEL,
                    fd,
                    std::ptr::null_mut(),
                )
            })
        }

        pub fn wait(
            &mut self,
            timeout: Duration,
            out: &mut Vec<u64>,
        ) -> io::Result<()> {
            let mut buf = [EpollEvent { events: 0, data: 0 }; 64];
            // sub-millisecond ticks round up to 1 ms, never down to a
            // busy-spinning 0
            let ms = timeout.as_millis().clamp(1, i32::MAX as u128) as i32;
            loop {
                let n = unsafe {
                    epoll_wait(self.epfd, buf.as_mut_ptr(), 64, ms)
                };
                if n < 0 {
                    let e = io::Error::last_os_error();
                    if e.kind() == io::ErrorKind::Interrupted {
                        continue;
                    }
                    return Err(e);
                }
                for ev in buf.iter().take(n as usize) {
                    // by-value read: a packed field must not be
                    // borrowed, only copied
                    let token = ev.data;
                    out.push(token);
                }
                return Ok(());
            }
        }
    }

    impl Drop for Impl {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }

    pub const BACKEND: &str = "epoll";
}

#[cfg(not(target_os = "linux"))]
mod sys {
    use super::*;

    /// Portable fallback: remember the registered tokens and report
    /// all of them as "maybe readable" after a short sleep. Callers
    /// read non-blocking, so a spurious wakeup costs one `WouldBlock`.
    pub struct Impl {
        tokens: Vec<u64>,
    }

    impl Impl {
        pub fn new() -> io::Result<Impl> {
            Ok(Impl { tokens: Vec::new() })
        }

        pub fn add(&mut self, _fd: i32, token: u64) -> io::Result<()> {
            self.tokens.push(token);
            Ok(())
        }

        pub fn del(&mut self, _fd: i32, token: u64) -> io::Result<()> {
            self.tokens.retain(|&t| t != token);
            Ok(())
        }

        pub fn wait(
            &mut self,
            timeout: Duration,
            out: &mut Vec<u64>,
        ) -> io::Result<()> {
            std::thread::sleep(timeout.min(Duration::from_millis(5)));
            out.extend_from_slice(&self.tokens);
            Ok(())
        }
    }

    pub const BACKEND: &str = "scan";
}

#[cfg(target_os = "linux")]
fn stream_fd(s: &TcpStream) -> i32 {
    use std::os::unix::io::AsRawFd;
    s.as_raw_fd()
}
#[cfg(not(target_os = "linux"))]
fn stream_fd(_s: &TcpStream) -> i32 {
    0
}

#[cfg(target_os = "linux")]
fn listener_fd(l: &TcpListener) -> i32 {
    use std::os::unix::io::AsRawFd;
    l.as_raw_fd()
}
#[cfg(not(target_os = "linux"))]
fn listener_fd(_l: &TcpListener) -> i32 {
    0
}

/// Read-readiness multiplexer over registered sockets. One instance
/// serves the whole server transport; `wait` is the only blocking
/// call in the poll loop.
pub struct Poller {
    inner: sys::Impl,
}

/// Name of the active readiness backend (`"epoll"` on Linux, `"scan"`
/// elsewhere) — surfaced in logs and the net_scale bench provenance.
pub const BACKEND: &str = sys::BACKEND;

impl Poller {
    pub fn new() -> io::Result<Poller> {
        Ok(Poller { inner: sys::Impl::new()? })
    }

    /// Watch a connected stream for read-readiness under `token`.
    /// The stream must already be (or soon be put) in non-blocking
    /// mode; the poller only observes it.
    pub fn register_stream(
        &mut self,
        stream: &TcpStream,
        token: u64,
    ) -> io::Result<()> {
        self.inner.add(stream_fd(stream), token)
    }

    /// Watch a listener: readable means at least one pending `accept`.
    pub fn register_listener(
        &mut self,
        listener: &TcpListener,
        token: u64,
    ) -> io::Result<()> {
        self.inner.add(listener_fd(listener), token)
    }

    /// Stop watching a stream. Both the fd (Linux) and the token
    /// (fallback) are needed to identify the registration.
    pub fn deregister_stream(
        &mut self,
        stream: &TcpStream,
        token: u64,
    ) -> io::Result<()> {
        self.inner.del(stream_fd(stream), token)
    }

    /// Block up to `timeout` for readiness; `out` is cleared and
    /// filled with the ready tokens (possibly none). Tokens may be
    /// stale — deregistered between wakeups — so callers must treat
    /// unknown tokens as no-ops.
    pub fn wait(
        &mut self,
        timeout: Duration,
        out: &mut Vec<u64>,
    ) -> io::Result<()> {
        out.clear();
        self.inner.wait(timeout, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::net::{TcpListener, TcpStream};

    fn pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let a = TcpStream::connect(addr).unwrap();
        let (b, _) = l.accept().unwrap();
        (a, b)
    }

    #[test]
    fn reports_readable_stream() {
        let (mut w, r) = pair();
        r.set_nonblocking(true).unwrap();
        let mut p = Poller::new().unwrap();
        p.register_stream(&r, 7).unwrap();
        w.write_all(b"x").unwrap();
        w.flush().unwrap();
        let mut out = Vec::new();
        // a written byte must surface within a few ticks
        let mut seen = false;
        for _ in 0..100 {
            p.wait(Duration::from_millis(50), &mut out).unwrap();
            if out.contains(&7) {
                seen = true;
                break;
            }
        }
        assert!(seen, "poller never reported the readable stream");
    }

    #[test]
    fn reports_pending_accept_on_listener() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        l.set_nonblocking(true).unwrap();
        let mut p = Poller::new().unwrap();
        p.register_listener(&l, 42).unwrap();
        let _c = TcpStream::connect(addr).unwrap();
        let mut out = Vec::new();
        let mut seen = false;
        for _ in 0..100 {
            p.wait(Duration::from_millis(50), &mut out).unwrap();
            if out.contains(&42) {
                seen = true;
                break;
            }
        }
        assert!(seen, "poller never reported the pending accept");
    }

    #[test]
    fn deregistered_stream_is_not_reported() {
        let (mut w, r) = pair();
        r.set_nonblocking(true).unwrap();
        let mut p = Poller::new().unwrap();
        p.register_stream(&r, 9).unwrap();
        p.deregister_stream(&r, 9).unwrap();
        w.write_all(b"x").unwrap();
        w.flush().unwrap();
        let mut out = Vec::new();
        for _ in 0..5 {
            p.wait(Duration::from_millis(10), &mut out).unwrap();
            assert!(
                !out.contains(&9),
                "deregistered token was still reported"
            );
        }
    }

    /// Real-epoll-only: silence means an empty wakeup (the scan
    /// fallback legitimately reports everything every tick).
    #[cfg(target_os = "linux")]
    #[test]
    fn silent_stream_yields_no_tokens() {
        let (_w, r) = pair();
        r.set_nonblocking(true).unwrap();
        let mut p = Poller::new().unwrap();
        p.register_stream(&r, 3).unwrap();
        let mut out = Vec::new();
        p.wait(Duration::from_millis(20), &mut out).unwrap();
        assert!(out.is_empty(), "spurious readiness on a silent stream");
    }

    /// The whole point: N sockets watched by one poller from one
    /// thread, each write individually observed.
    #[test]
    fn multiplexes_many_streams_one_thread() {
        let n = 16;
        let mut p = Poller::new().unwrap();
        let mut writers = Vec::new();
        let mut readers = Vec::new();
        for i in 0..n {
            let (w, r) = pair();
            r.set_nonblocking(true).unwrap();
            p.register_stream(&r, i).unwrap();
            writers.push(w);
            readers.push(r);
        }
        for w in &mut writers {
            w.write_all(b"y").unwrap();
            w.flush().unwrap();
        }
        let mut seen = vec![false; n as usize];
        let mut out = Vec::new();
        for _ in 0..200 {
            p.wait(Duration::from_millis(20), &mut out).unwrap();
            for &t in &out {
                if (t as usize) < seen.len() {
                    seen[t as usize] = true;
                }
            }
            if seen.iter().all(|&s| s) {
                break;
            }
        }
        assert!(
            seen.iter().all(|&s| s),
            "not every readable stream was reported: {seen:?}"
        );
    }
}
