//! Thread-safe execution engine — loads AOT HLO-text artifacts,
//! compiles each once per process, and executes them from the round
//! loop, concurrently from any number of cohort worker threads.
//!
//! Concurrency contract (the runtime leg of the parallel client
//! pipeline):
//!
//! * the executable cache is an `RwLock<HashMap<..>>` of `Arc`'d
//!   executables — the hot path takes a read lock only long enough to
//!   clone the `Arc`, then executes outside every lock;
//! * compile-once semantics are enforced by a dedicated compile mutex
//!   with a double-check, so a cold artifact is parsed + compiled by
//!   exactly one thread while others wait (first-compile of *distinct*
//!   artifacts serializes too — a startup-only cost);
//! * [`EngineStats`] accumulation is atomic (relaxed counters), so
//!   workers never contend on a stats lock.
//!
//! `Engine` is `Send + Sync` (asserted by a compile-time test); the
//! actual HLO dispatch is delegated to [`super::backend`], which is
//! the real PJRT client under `--features xla` and a stub otherwise.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use anyhow::{Context, Result};

use super::backend;

/// Typed input argument for an artifact execution.
pub enum In<'a> {
    F32(&'a [f32], &'a [i64]),
    I32(&'a [i32], &'a [i64]),
    ScalarF32(f32),
    ScalarI32(i32),
}

/// Cumulative execution statistics (perf accounting, §Perf).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub compilations: u64,
    pub executions: u64,
    pub compile_ns: u64,
    pub execute_ns: u64,
    pub marshal_ns: u64,
}

/// Lock-free stats accumulation; counters are independent, so relaxed
/// ordering is sufficient (readers only ever see a consistent-enough
/// snapshot for reporting).
#[derive(Default)]
struct AtomicStats {
    compilations: AtomicU64,
    executions: AtomicU64,
    compile_ns: AtomicU64,
    execute_ns: AtomicU64,
    marshal_ns: AtomicU64,
}

impl AtomicStats {
    fn snapshot(&self) -> EngineStats {
        EngineStats {
            compilations: self.compilations.load(Ordering::Relaxed),
            executions: self.executions.load(Ordering::Relaxed),
            compile_ns: self.compile_ns.load(Ordering::Relaxed),
            execute_ns: self.execute_ns.load(Ordering::Relaxed),
            marshal_ns: self.marshal_ns.load(Ordering::Relaxed),
        }
    }
}

pub struct Engine {
    client: backend::Client,
    dir: PathBuf,
    cache: RwLock<HashMap<String, Arc<backend::Executable>>>,
    compile_lock: Mutex<()>,
    stats: AtomicStats,
}

impl Engine {
    pub fn new(artifact_dir: &Path) -> Result<Engine> {
        let client = backend::Client::cpu()?;
        Ok(Engine {
            client,
            dir: artifact_dir.to_path_buf(),
            cache: RwLock::new(HashMap::new()),
            compile_lock: Mutex::new(()),
            stats: AtomicStats::default(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile-once artifact lookup (keyed by file name).
    fn executable(&self, file: &str) -> Result<Arc<backend::Executable>> {
        if let Some(exe) = self
            .cache
            .read()
            .expect("engine cache poisoned")
            .get(file)
        {
            return Ok(exe.clone());
        }
        let _compiling = self
            .compile_lock
            .lock()
            .expect("engine compile lock poisoned");
        // double-check: another thread may have compiled `file` while
        // we waited on the compile lock
        if let Some(exe) = self
            .cache
            .read()
            .expect("engine cache poisoned")
            .get(file)
        {
            return Ok(exe.clone());
        }
        let t = Instant::now();
        let path = self.dir.join(file);
        let exe = Arc::new(
            self.client
                .compile_hlo_text(&path)
                .with_context(|| format!("compiling {file}"))?,
        );
        self.stats.compilations.fetch_add(1, Ordering::Relaxed);
        self.stats
            .compile_ns
            .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.cache
            .write()
            .expect("engine cache poisoned")
            .insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact; returns the flattened output tuple.
    /// Safe to call concurrently from many threads.
    pub fn execute(
        &self,
        file: &str,
        inputs: &[In],
    ) -> Result<Vec<backend::Value>> {
        let exe = self.executable(file)?;
        let tm = Instant::now();
        let prepared = backend::prepare(inputs)?;
        let marshal_ns = tm.elapsed().as_nanos() as u64;
        let t = Instant::now();
        let parts = exe.run(&prepared)?;
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        self.stats
            .execute_ns
            .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.stats
            .marshal_ns
            .fetch_add(marshal_ns, Ordering::Relaxed);
        Ok(parts)
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.snapshot()
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.read().expect("engine cache poisoned").len()
    }
}

/// Extract a f32 vector from an output value.
pub fn f32_vec(v: &backend::Value) -> Result<Vec<f32>> {
    v.f32_vec()
}

/// Extract a f32 scalar.
pub fn f32_scalar(v: &backend::Value) -> Result<f32> {
    v.f32_scalar()
}

/// Extract an i32 scalar.
pub fn i32_scalar(v: &backend::Value) -> Result<i32> {
    v.i32_scalar()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Engine>();
    }

    #[test]
    fn shared_engine_across_threads() {
        let eng = Engine::new(Path::new("artifacts")).unwrap();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let _ = eng.platform();
                    assert_eq!(eng.stats().executions, 0);
                });
            }
        });
        assert_eq!(eng.compiled_count(), 0);
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_backend_fails_with_actionable_error() {
        let eng = Engine::new(Path::new("artifacts")).unwrap();
        let err = eng
            .execute("local_update_det.hlo", &[In::ScalarI32(1)])
            .unwrap_err();
        let msg = format!("{err:?}");
        assert!(msg.contains("stub execution backend"), "{msg}");
        assert!(msg.contains("--features xla"), "{msg}");
    }
}
