//! PJRT execution engine — loads AOT HLO-text artifacts, compiles each
//! once per process, and executes them from the round loop.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax
//! >= 0.5 serializes protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md and python/compile/aot.py).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable,
          XlaComputation};

/// Typed input argument for an artifact execution.
pub enum In<'a> {
    F32(&'a [f32], &'a [i64]),
    I32(&'a [i32], &'a [i64]),
    ScalarF32(f32),
    ScalarI32(i32),
}

impl<'a> In<'a> {
    fn literal(&self) -> Result<Literal> {
        Ok(match self {
            In::F32(v, dims) => Literal::vec1(v).reshape(dims)?,
            In::I32(v, dims) => Literal::vec1(v).reshape(dims)?,
            In::ScalarF32(v) => Literal::scalar(*v),
            In::ScalarI32(v) => Literal::scalar(*v),
        })
    }
}

/// Cumulative execution statistics (perf accounting, §Perf).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub compilations: u64,
    pub executions: u64,
    pub compile_ns: u64,
    pub execute_ns: u64,
    pub marshal_ns: u64,
}

pub struct Engine {
    client: PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, PjRtLoadedExecutable>>,
    stats: RefCell<EngineStats>,
}

impl Engine {
    pub fn new(artifact_dir: &Path) -> Result<Engine> {
        let client =
            PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            dir: artifact_dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile-once artifact loading (keyed by file name).
    fn ensure_compiled(&self, file: &str) -> Result<()> {
        if self.cache.borrow().contains_key(file) {
            return Ok(());
        }
        let t = Instant::now();
        let path = self.dir.join(file);
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {file}"))?;
        let mut st = self.stats.borrow_mut();
        st.compilations += 1;
        st.compile_ns += t.elapsed().as_nanos() as u64;
        self.cache.borrow_mut().insert(file.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact; returns the flattened output tuple.
    pub fn execute(&self, file: &str, inputs: &[In]) -> Result<Vec<Literal>> {
        self.ensure_compiled(file)?;
        let tm = Instant::now();
        let lits: Vec<Literal> = inputs
            .iter()
            .map(|i| i.literal())
            .collect::<Result<_>>()?;
        let marshal_ns = tm.elapsed().as_nanos() as u64;
        let t = Instant::now();
        let cache = self.cache.borrow();
        let exe = cache.get(file).unwrap();
        let result = exe.execute::<Literal>(&lits)?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple
        let parts = result.to_tuple()?;
        let mut st = self.stats.borrow_mut();
        st.executions += 1;
        st.execute_ns += t.elapsed().as_nanos() as u64;
        st.marshal_ns += marshal_ns;
        Ok(parts)
    }

    pub fn stats(&self) -> EngineStats {
        *self.stats.borrow()
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// Extract a f32 vector from an output literal.
pub fn f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a f32 scalar.
pub fn f32_scalar(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Extract an i32 scalar.
pub fn i32_scalar(lit: &Literal) -> Result<i32> {
    Ok(lit.get_first_element::<i32>()?)
}
