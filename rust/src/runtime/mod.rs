//! PJRT runtime: artifact registry (manifest) + thread-safe execution
//! engine over a pluggable backend (real PJRT under `--features xla`,
//! stub otherwise).

pub mod backend;
pub mod engine;
pub mod manifest;

pub use engine::{Engine, EngineStats, In};
pub use manifest::{default_dir, Manifest, ModelInfo};

/// True when the AOT artifacts (manifest.json) are present.
pub fn artifacts_available() -> bool {
    default_dir().join("manifest.json").exists()
}

/// Artifact gate for tests and benches. Returns `true` when artifacts
/// exist; otherwise prints a clear skip message and returns `false` —
/// unless `FEDFP8_REQUIRE_ARTIFACTS` is set, in which case the absence
/// is a hard failure (CI configurations that *do* bake artifacts use
/// this to keep the gated tests honest).
pub fn artifacts_or_skip(what: &str) -> bool {
    if artifacts_available() {
        return true;
    }
    if std::env::var_os("FEDFP8_REQUIRE_ARTIFACTS").is_some() {
        panic!(
            "FEDFP8_REQUIRE_ARTIFACTS is set but {}/manifest.json is \
             missing — run `make artifacts` first (needed by: {what})",
            default_dir().display()
        );
    }
    eprintln!(
        "skip {what}: AOT artifacts not built (run `make artifacts`; \
         set FEDFP8_REQUIRE_ARTIFACTS=1 to fail instead of skipping)"
    );
    false
}

/// Like [`artifacts_or_skip`] but gates on one specific artifact file
/// (e.g. `golden_fp8.json`), so the env-var hard gate cannot be
/// silently bypassed by an individually missing file.
pub fn artifact_file_or_skip(
    file: &str,
    what: &str,
) -> Option<std::path::PathBuf> {
    let p = default_dir().join(file);
    if p.exists() {
        return Some(p);
    }
    if std::env::var_os("FEDFP8_REQUIRE_ARTIFACTS").is_some() {
        panic!(
            "FEDFP8_REQUIRE_ARTIFACTS is set but {} is missing — run \
             `make artifacts` first (needed by: {what})",
            p.display()
        );
    }
    eprintln!(
        "skip {what}: {} not built (run `make artifacts`; set \
         FEDFP8_REQUIRE_ARTIFACTS=1 to fail instead of skipping)",
        p.display()
    );
    None
}
