//! PJRT runtime: artifact registry (manifest) + execution engine.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, In};
pub use manifest::{default_dir, Manifest, ModelInfo};
