//! Execution backend behind [`super::engine::Engine`].
//!
//! Two compile-time implementations of one narrow contract
//! (`Client` / `Executable` / `Value` / [`prepare`]):
//!
//! * `xla` feature ON — the real PJRT backend: parses AOT HLO-text
//!   artifacts, compiles them on the process-wide PJRT CPU client and
//!   executes them. Requires the `xla` bindings crate (xla_extension);
//!   see ARCHITECTURE.md §Execution backends.
//! * `xla` feature OFF (default) — a stub that supports engine
//!   construction and platform queries but fails artifact compilation
//!   with an actionable error. This keeps the whole coordinator /
//!   codec / protocol stack building and testing on machines without
//!   the XLA toolchain: everything except HLO dispatch is real.
//!
//! Thread-safety contract: `Client` and `Executable` must be
//! `Send + Sync` — the engine shares one client across the cohort
//! worker threads and executes the same loaded executable
//! concurrently. PJRT guarantees this (client compilation and
//! `Execute` are thread-safe in the PJRT C API); the stub types are
//! plain data.

#[cfg(feature = "xla")]
mod imp {
    use std::path::Path;

    use anyhow::{Context, Result};
    use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable,
              XlaComputation};

    use crate::runtime::engine::In;

    pub struct Client(PjRtClient);
    pub struct Executable(PjRtLoadedExecutable);
    pub struct Value(Literal);
    /// Marshalled input literals, ready for dispatch.
    pub struct Prepared(Vec<Literal>);

    // SAFETY: the wrappers own their underlying PJRT/XLA objects and
    // never hand out aliased raw pointers. The PJRT C API specifies
    // that clients and loaded executables are thread-safe (concurrent
    // Compile/Execute calls are supported), and `Literal` is an owned
    // host-side buffer with no interior mutability. The Rust bindings
    // only lack the auto-traits because they hold raw pointers.
    unsafe impl Send for Client {}
    unsafe impl Sync for Client {}
    unsafe impl Send for Executable {}
    unsafe impl Sync for Executable {}
    unsafe impl Send for Value {}
    unsafe impl Sync for Value {}

    impl Client {
        pub fn cpu() -> Result<Client> {
            Ok(Client(
                PjRtClient::cpu().context("creating PJRT CPU client")?,
            ))
        }

        pub fn platform_name(&self) -> String {
            self.0.platform_name()
        }

        /// Parse + compile one HLO-text artifact.
        ///
        /// Interchange is HLO *text* (`HloModuleProto::from_text_file`):
        /// jax >= 0.5 serializes protos with 64-bit instruction ids
        /// that xla_extension 0.5.1 rejects; the text parser reassigns
        /// ids (see /opt/xla-example/README.md, python/compile/aot.py).
        pub fn compile_hlo_text(&self, path: &Path) -> Result<Executable> {
            let proto = HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| {
                format!("parsing HLO text {}", path.display())
            })?;
            let comp = XlaComputation::from_proto(&proto);
            Ok(Executable(self.0.compile(&comp).with_context(|| {
                format!("compiling {}", path.display())
            })?))
        }
    }

    /// Marshal typed inputs into device literals.
    pub fn prepare(inputs: &[In]) -> Result<Prepared> {
        let lits = inputs
            .iter()
            .map(|i| {
                Ok(match i {
                    In::F32(v, dims) => Literal::vec1(v).reshape(dims)?,
                    In::I32(v, dims) => Literal::vec1(v).reshape(dims)?,
                    In::ScalarF32(v) => Literal::scalar(*v),
                    In::ScalarI32(v) => Literal::scalar(*v),
                })
            })
            .collect::<Result<Vec<Literal>>>()?;
        Ok(Prepared(lits))
    }

    impl Executable {
        /// Execute; returns the flattened output tuple
        /// (aot.py lowers with return_tuple=True: always a tuple).
        pub fn run(&self, inputs: &Prepared) -> Result<Vec<Value>> {
            let result = self.0.execute::<Literal>(&inputs.0)?[0][0]
                .to_literal_sync()?;
            Ok(result.to_tuple()?.into_iter().map(Value).collect())
        }
    }

    impl Value {
        pub fn f32_vec(&self) -> Result<Vec<f32>> {
            Ok(self.0.to_vec::<f32>()?)
        }

        pub fn f32_scalar(&self) -> Result<f32> {
            Ok(self.0.get_first_element::<f32>()?)
        }

        pub fn i32_scalar(&self) -> Result<i32> {
            Ok(self.0.get_first_element::<i32>()?)
        }
    }
}

#[cfg(not(feature = "xla"))]
mod imp {
    use std::path::Path;

    use anyhow::{bail, Result};

    use crate::runtime::engine::In;

    pub struct Client;
    /// Uninhabited: the stub can never produce an executable, so code
    /// paths "after compilation" are provably unreachable.
    pub enum Executable {}
    pub enum Value {}
    pub struct Prepared;

    impl Client {
        pub fn cpu() -> Result<Client> {
            Ok(Client)
        }

        pub fn platform_name(&self) -> String {
            "stub (enable the `xla` feature for PJRT)".to_string()
        }

        pub fn compile_hlo_text(&self, path: &Path) -> Result<Executable> {
            bail!(
                "cannot compile {}: this build uses the stub execution \
                 backend — rebuild with `--features xla` (plus the xla \
                 bindings crate, see ARCHITECTURE.md) to execute AOT \
                 artifacts",
                path.display()
            )
        }
    }

    pub fn prepare(_inputs: &[In]) -> Result<Prepared> {
        Ok(Prepared)
    }

    impl Executable {
        pub fn run(&self, _inputs: &Prepared) -> Result<Vec<Value>> {
            match *self {}
        }
    }

    impl Value {
        pub fn f32_vec(&self) -> Result<Vec<f32>> {
            match *self {}
        }

        pub fn f32_scalar(&self) -> Result<f32> {
            match *self {}
        }

        pub fn i32_scalar(&self) -> Result<i32> {
            match *self {}
        }
    }
}

pub use imp::{prepare, Client, Executable, Prepared, Value};
