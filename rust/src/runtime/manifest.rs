//! `manifest.json` loader — the contract between `python/compile/aot.py`
//! and the Rust coordinator: model dimensions, segment tables,
//! artifact file names and baked-in batch shapes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::fp8::codec::Segment;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub dim: usize,
    pub alpha_dim: usize,
    pub n_act: usize,
    pub classes: usize,
    pub kind: String,
    pub input_shape: Vec<usize>,
    pub u_steps: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub server_p: usize,
    pub optimizer: String,
    pub segments: Vec<Segment>,
    pub artifacts: BTreeMap<String, String>,
    pub init: BTreeMap<String, String>,
}

impl ModelInfo {
    /// HLO file for a graph ("local_update"/"evaluate"/"server_opt")
    /// and QAT mode ("det"/"rand"/"none").
    pub fn artifact(&self, graph: &str, mode: &str) -> Result<&str> {
        let key = format!("{graph}_{mode}");
        match self.artifacts.get(&key) {
            Some(f) => Ok(f),
            None => bail!(
                "model '{}' has no artifact '{key}' (exported: {:?})",
                self.name,
                self.artifacts.keys().collect::<Vec<_>>()
            ),
        }
    }

    pub fn feat_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Count of unquantized parameters (travel as f32 on the wire).
    pub fn raw_params(&self) -> usize {
        self.segments
            .iter()
            .filter(|s| !s.quantized)
            .map(|s| s.size)
            .sum()
    }

    /// Count of quantized parameters (travel as 1-byte codes).
    pub fn quant_params(&self) -> usize {
        self.dim - self.raw_params()
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelInfo>,
    pub quant_demo: Option<(String, usize)>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let root = Json::parse(&text)?;
        let mut models = BTreeMap::new();
        for (name, m) in root.get("models")?.as_obj()? {
            let mut segments = Vec::new();
            for s in m.get("segments")?.as_arr()? {
                segments.push(Segment {
                    name: s.get("name")?.as_str()?.to_string(),
                    offset: s.get("offset")?.as_usize()?,
                    size: s.get("size")?.as_usize()?,
                    quantized: s.get("quantized")?.as_bool()?,
                    alpha_idx: s
                        .opt("alpha_idx")
                        .map(|v| v.as_usize())
                        .transpose()?,
                });
            }
            let strmap = |key: &str| -> Result<BTreeMap<String, String>> {
                let mut out = BTreeMap::new();
                for (k, v) in m.get(key)?.as_obj()? {
                    out.insert(k.clone(), v.as_str()?.to_string());
                }
                Ok(out)
            };
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    dim: m.get("dim")?.as_usize()?,
                    alpha_dim: m.get("alpha_dim")?.as_usize()?,
                    n_act: m.get("n_act")?.as_usize()?,
                    classes: m.get("classes")?.as_usize()?,
                    kind: m.get("kind")?.as_str()?.to_string(),
                    input_shape: m
                        .get("input_shape")?
                        .as_arr()?
                        .iter()
                        .map(|v| v.as_usize())
                        .collect::<Result<_>>()?,
                    u_steps: m.get("u_steps")?.as_usize()?,
                    batch: m.get("batch")?.as_usize()?,
                    eval_batch: m.get("eval_batch")?.as_usize()?,
                    server_p: m.get("server_p")?.as_usize()?,
                    optimizer: m.get("optimizer")?.as_str()?.to_string(),
                    segments,
                    artifacts: strmap("artifacts")?,
                    init: strmap("init")?,
                },
            );
        }
        let quant_demo = root.opt("quant_demo").and_then(|q| {
            Some((
                q.get("file").ok()?.as_str().ok()?.to_string(),
                q.get("n").ok()?.as_usize().ok()?,
            ))
        });
        Ok(Manifest {
            dir: dir.to_path_buf(),
            models,
            quant_demo,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models.get(name).with_context(|| {
            format!(
                "unknown model '{name}' (available: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Read a little-endian f32 init file declared by the manifest.
    pub fn load_init(&self, model: &ModelInfo, tag: &str) -> Result<Vec<f32>> {
        let file = model
            .init
            .get(tag)
            .with_context(|| format!("no init '{tag}'"))?;
        let bytes = std::fs::read(self.dir.join(file))?;
        if bytes.len() % 4 != 0 {
            bail!("init file {file} not a multiple of 4 bytes");
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Default artifacts directory: $FEDFP8_ARTIFACTS or ./artifacts.
pub fn default_dir() -> PathBuf {
    std::env::var_os("FEDFP8_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_real_manifest_if_present() {
        let dir = default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        assert!(man.models.contains_key("lenet_c10"));
        let m = man.model("lenet_c10").unwrap();
        assert_eq!(
            m.segments.iter().map(|s| s.size).sum::<usize>(),
            m.dim
        );
        let w = man.load_init(m, "w").unwrap();
        assert_eq!(w.len(), m.dim);
        let a = man.load_init(m, "alpha").unwrap();
        assert_eq!(a.len(), m.alpha_dim);
        // alpha init covers the segment max-abs (paper init rule)
        for seg in m.segments.iter().filter(|s| s.quantized) {
            let mx = w[seg.offset..seg.offset + seg.size]
                .iter()
                .fold(0f32, |m, v| m.max(v.abs()));
            assert!(a[seg.alpha_idx.unwrap()] >= mx - 1e-6);
        }
    }
}
