//! Table 1 — final test accuracy and communication gain vs FP32
//! FedAvg for FP8FedAvg-UQ and FP8FedAvg-UQ+ across the model/dataset/
//! split grid.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::{comm_gain, mean_std};
use crate::runtime::{default_dir, Engine, Manifest};
use crate::util::cli::Args;

use super::{run_one, scaled, seeds_from, wall_clock_line};

/// The paper's grid, mapped onto our reduced-scale variants.
pub fn default_rows() -> Vec<(&'static str, &'static str)> {
    vec![
        ("lenet_c10", "iid"),
        ("lenet_c10", "dir03"),
        ("resnet8_c10", "iid"),
        ("resnet8_c10", "dir03"),
        ("lenet_c100", "iid"),
        ("lenet_c100", "dir03"),
        ("resnet8_c100", "iid"),
        ("resnet8_c100", "dir03"),
        ("matchbox", "iid"),
        ("matchbox", "speaker"),
        ("kwt", "iid"),
        ("kwt", "speaker"),
    ]
}

pub fn run(args: &Args) -> Result<()> {
    let dir = default_dir();
    let engine = Engine::new(&dir)?;
    let manifest = Manifest::load(&dir)?;
    let seeds = seeds_from(args)?;
    let rows: Vec<(String, String)> = match args.get("models") {
        Some(list) => list
            .split(',')
            .flat_map(|m| {
                ["iid", "dir03"].iter().filter_map(move |s| {
                    let speech = m == "matchbox" || m == "kwt";
                    let split = if speech && *s == "dir03" {
                        "speaker"
                    } else {
                        s
                    };
                    Some((m.to_string(), split.to_string()))
                })
            })
            .collect(),
        None => default_rows()
            .into_iter()
            .map(|(a, b)| (a.to_string(), b.to_string()))
            .collect(),
    };

    println!(
        "\nTable 1 — final accuracy / comm gain vs FP32 \
         (seeds={}, reduced scale)\n",
        seeds.len()
    );
    println!(
        "{:<14} {:<8} {:>16} {:>20} {:>20}",
        "model", "split", "FP32 FedAvg", "FP8FedAvg-UQ", "FP8FedAvg-UQ+"
    );
    println!("{}", "-".repeat(84));

    let mut wall_secs = 0.0f64;
    let mut runs = 0usize;
    for (model, split) in rows {
        let mut acc = vec![vec![]; 3];
        let mut gains = vec![vec![]; 3];
        for &seed in &seeds {
            let mut results = Vec::new();
            for method in ["fp32", "uq", "uq+"] {
                let mut cfg = scaled(
                    ExperimentConfig::base(&model)?
                        .with_method(method)?
                        .with_split(&split)?,
                    args,
                    40,
                )?;
                cfg.seed = seed;
                results.push(run_one(&engine, &manifest, cfg, false)?);
            }
            for (i, r) in results.iter().enumerate() {
                acc[i].push(r.best_accuracy() * 100.0);
                let (_, g) = comm_gain(&results[0], r);
                gains[i].push(g);
                wall_secs += r.wall_secs;
                runs += 1;
            }
        }
        let cell = |i: usize| {
            let (am, astd) = mean_std(&acc[i]);
            let (gm, _) = mean_std(&gains[i]);
            format!("{am:5.1}±{astd:3.1}/{gm:4.1}x")
        };
        println!(
            "{:<14} {:<8} {:>16} {:>20} {:>20}",
            model,
            split,
            cell(0),
            cell(1),
            cell(2)
        );
    }
    println!(
        "\n(gain = FP32 bytes-to-acc* / method bytes-to-acc*, acc* = \
         best accuracy reached by both; paper Table 1 definition)"
    );
    println!("{}", wall_clock_line(args, runs, wall_secs)?);
    Ok(())
}
