//! Table 2 — ablation of deterministic/stochastic quantization in
//! on-device QAT and in client<->server communication (CIFAR100-iid
//! stand-in). Validates Remarks 3-5: det QAT > rand QAT, and rand CQ
//! >> det CQ (biased communication hurts convergence).

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::mean_std;
use crate::runtime::{default_dir, Engine, Manifest};
use crate::util::cli::Args;

use super::{run_one, scaled, seeds_from, wall_clock_line};

/// The four ablation arms, in the paper's column order:
/// (det QAT, no CQ), (rand QAT, no CQ), (det QAT, det CQ),
/// (det QAT, rand CQ).
pub const ARMS: [(&str, &str); 4] = [
    ("nocq_det", "det. QAT"),
    ("nocq_rand", "rand. QAT"),
    ("bq", "det. CQ"),
    ("uq", "rand. CQ"),
];

pub fn run(args: &Args) -> Result<()> {
    let dir = default_dir();
    let engine = Engine::new(&dir)?;
    let manifest = Manifest::load(&dir)?;
    let seeds = seeds_from(args)?;
    let models: Vec<String> = args
        .get_or("models", "lenet_c100,resnet8_c100")
        .split(',')
        .map(String::from)
        .collect();

    println!(
        "\nTable 2 — det/rand QAT x det/rand CQ, final accuracy \
         (iid, seeds={})\n",
        seeds.len()
    );
    println!(
        "{:<14} | {:>12} {:>12} | {:>12} {:>12}",
        "", "FP8 QAT", "without CQ", "FP8 det. QAT", "with CQ"
    );
    println!(
        "{:<14} | {:>12} {:>12} | {:>12} {:>12}",
        "model", ARMS[0].1, ARMS[1].1, ARMS[2].1, ARMS[3].1
    );
    println!("{}", "-".repeat(72));

    let mut wall_secs = 0.0f64;
    let mut runs = 0usize;
    for model in &models {
        let mut cells = Vec::new();
        for (method, _) in ARMS {
            let mut accs = Vec::new();
            for &seed in &seeds {
                let mut cfg = scaled(
                    ExperimentConfig::base(model)?
                        .with_method(method)?
                        .with_split("iid")?,
                    args,
                    40,
                )?;
                cfg.seed = seed;
                let r = run_one(&engine, &manifest, cfg, false)?;
                accs.push(r.best_accuracy() * 100.0);
                wall_secs += r.wall_secs;
                runs += 1;
            }
            let (m, s) = mean_std(&accs);
            cells.push(format!("{m:5.1}±{s:3.1}"));
        }
        println!(
            "{:<14} | {:>12} {:>12} | {:>12} {:>12}",
            model, cells[0], cells[1], cells[2], cells[3]
        );
    }
    println!(
        "\n(expected shape per paper: det QAT >= rand QAT; \
         rand CQ >> det CQ)"
    );
    println!("{}", wall_clock_line(args, runs, wall_secs)?);
    Ok(())
}
