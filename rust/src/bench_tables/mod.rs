//! Regenerators for every table and figure in the paper's evaluation
//! (§4): Table 1 (accuracy + communication gain), Table 2 (quantizer
//! ablation), Figure 2 (accuracy vs communication cost).
//!
//! Scale note: the paper trains R=1000/500 rounds on CIFAR/Speech with
//! K=100/2112 clients on GPU clusters; defaults here are reduced
//! presets sized for the CPU testbed (override with --rounds/--seeds/
//! --clients). The comparisons — who wins, roughly by what factor —
//! are what transfer; see EXPERIMENTS.md.

pub mod fig2;
pub mod table1;
pub mod table2;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::{RunResult, Server};
use crate::fp8::simd::KernelKind;
use crate::runtime::{Engine, Manifest};
use crate::util::cli::Args;

/// Run one config to completion, writing its CSV curve.
pub fn run_one(
    engine: &Engine,
    manifest: &Manifest,
    cfg: ExperimentConfig,
    verbose: bool,
) -> Result<RunResult> {
    let name = cfg.name.clone();
    let mut server = Server::new(engine, manifest, cfg)?;
    server.set_verbose(verbose);
    let result = server.run()?;
    let csv = manifest
        .dir
        .join("results")
        .join(format!("{name}_s{}.csv", server.cfg.seed));
    result.to_csv(&csv)?;
    Ok(result)
}

/// Common experiment-scale overrides shared by the regenerators.
/// `--fp8-kernel` rides along with the wall-clock knobs: like
/// `--parallelism` it changes run time, never metrics (every kernel
/// is bit-identical — the conformance-harness contract, smoke-tested
/// end-to-end by `tests/parallel_determinism.rs`).
pub fn scaled(
    mut cfg: ExperimentConfig,
    args: &Args,
    default_rounds: usize,
) -> Result<ExperimentConfig> {
    cfg.rounds = args.parse_or("rounds", default_rounds)?;
    cfg.clients = args.parse_or("clients", cfg.clients)?;
    cfg.n_train = args.parse_or("n-train", cfg.n_train)?;
    cfg.n_test = args.parse_or("n-test", cfg.n_test)?;
    cfg.eval_every = args.parse_or("eval-every", cfg.eval_every)?;
    cfg.parallelism = args.parse_or("parallelism", cfg.parallelism)?;
    cfg.fp8_kernel = args.parse_or("fp8-kernel", cfg.fp8_kernel)?;
    Ok(cfg)
}

pub fn seeds_from(args: &Args) -> Result<Vec<u64>> {
    let n: usize = args.parse_or("seeds", 2usize)?;
    Ok((1..=n as u64).collect())
}

/// The kernel the drivers are running with (for wall-clock reports):
/// the `--fp8-kernel` choice plus what it resolves to on this host.
pub fn kernel_label(args: &Args) -> Result<String> {
    let kind: KernelKind =
        args.parse_or("fp8-kernel", KernelKind::Auto)?;
    Ok(format!("{kind} ({})", kind.resolve().name()))
}

/// One-line wall-clock summary for a driver's report: total seconds
/// across `runs` experiments, tagged with the active FP8 kernel so
/// A/B timings of `--fp8-kernel scalar` vs `simd` are self-labelled.
pub fn wall_clock_line(
    args: &Args,
    runs: usize,
    wall_secs: f64,
) -> Result<String> {
    Ok(format!(
        "wall-clock: {wall_secs:.1}s across {runs} runs  \
         [fp8-kernel={}]  (timing-only knob: metrics are \
         bit-identical across kernels)",
        kernel_label(args)?,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn scaled_wires_the_fp8_kernel_knob() {
        let base =
            ExperimentConfig::preset("lenet_c10:uq:iid").unwrap();
        let cfg = scaled(base.clone(), &args("--fp8-kernel scalar"), 10)
            .unwrap();
        assert_eq!(cfg.fp8_kernel, KernelKind::Scalar);
        // a wall-clock knob: the metric fingerprint must not move
        assert_eq!(cfg.fingerprint(), {
            let mut b = base.clone();
            b.rounds = cfg.rounds;
            b.fingerprint()
        });
        // default passes through untouched
        let cfg = scaled(base.clone(), &args(""), 10).unwrap();
        assert_eq!(cfg.fp8_kernel, KernelKind::Auto);
        // bad values are typed errors
        assert!(scaled(base, &args("--fp8-kernel turbo"), 10).is_err());
    }

    #[test]
    fn wall_clock_line_names_the_kernel() {
        let line =
            wall_clock_line(&args("--fp8-kernel scalar"), 3, 1.25)
                .unwrap();
        assert!(line.contains("3 runs"), "{line}");
        assert!(line.contains("fp8-kernel=scalar"), "{line}");
        assert!(line.contains("scalar ("), "{line}");
        assert!(
            kernel_label(&args("")).unwrap().starts_with("auto"),
        );
    }
}
