//! Regenerators for every table and figure in the paper's evaluation
//! (§4): Table 1 (accuracy + communication gain), Table 2 (quantizer
//! ablation), Figure 2 (accuracy vs communication cost).
//!
//! Scale note: the paper trains R=1000/500 rounds on CIFAR/Speech with
//! K=100/2112 clients on GPU clusters; defaults here are reduced
//! presets sized for the CPU testbed (override with --rounds/--seeds/
//! --clients). The comparisons — who wins, roughly by what factor —
//! are what transfer; see EXPERIMENTS.md.

pub mod fig2;
pub mod table1;
pub mod table2;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::{RunResult, Server};
use crate::runtime::{Engine, Manifest};
use crate::util::cli::Args;

/// Run one config to completion, writing its CSV curve.
pub fn run_one(
    engine: &Engine,
    manifest: &Manifest,
    cfg: ExperimentConfig,
    verbose: bool,
) -> Result<RunResult> {
    let name = cfg.name.clone();
    let mut server = Server::new(engine, manifest, cfg)?;
    server.set_verbose(verbose);
    let result = server.run()?;
    let csv = manifest
        .dir
        .join("results")
        .join(format!("{name}_s{}.csv", server.cfg.seed));
    result.to_csv(&csv)?;
    Ok(result)
}

/// Common experiment-scale overrides shared by the regenerators.
pub fn scaled(
    mut cfg: ExperimentConfig,
    args: &Args,
    default_rounds: usize,
) -> Result<ExperimentConfig> {
    cfg.rounds = args.parse_or("rounds", default_rounds)?;
    cfg.clients = args.parse_or("clients", cfg.clients)?;
    cfg.n_train = args.parse_or("n-train", cfg.n_train)?;
    cfg.n_test = args.parse_or("n-test", cfg.n_test)?;
    cfg.eval_every = args.parse_or("eval-every", cfg.eval_every)?;
    cfg.parallelism = args.parse_or("parallelism", cfg.parallelism)?;
    Ok(cfg)
}

pub fn seeds_from(args: &Args) -> Result<Vec<u64>> {
    let n: usize = args.parse_or("seeds", 2usize)?;
    Ok((1..=n as u64).collect())
}
