//! Figure 2 — server test accuracy versus cumulative communication
//! cost for FP32 FedAvg, FP8 QAT with biased (BQ) / unbiased (UQ)
//! communication, and UQ+ (ServerOptimize).
//!
//! Emits one CSV per method under `artifacts/results/fig2_*.csv`
//! (columns: cum_bytes, accuracy) plus a coarse ASCII rendering so the
//! crossover structure is visible straight from the terminal.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::RunResult;
use crate::runtime::{default_dir, Engine, Manifest};
use crate::util::cli::Args;

use super::{run_one, scaled, wall_clock_line};

pub const METHODS: [&str; 4] = ["fp32", "bq", "uq", "uq+"];

pub fn run(args: &Args) -> Result<()> {
    let dir = default_dir();
    let engine = Engine::new(&dir)?;
    let manifest = Manifest::load(&dir)?;
    let model = args.get_or("model", "lenet_c10");
    let split = args.get_or("split", "iid");
    let seed: u64 = args.parse_or("seed", 1u64)?;

    let mut results: Vec<RunResult> = Vec::new();
    for method in METHODS {
        let mut cfg = scaled(
            ExperimentConfig::base(&model)?
                .with_method(method)?
                .with_split(&split)?,
            args,
            50,
        )?;
        cfg.seed = seed;
        cfg.eval_every = 1; // dense curve
        eprintln!("[fig2] running {} ...", cfg.name);
        let r = run_one(&engine, &manifest, cfg, false)?;
        let csv = dir
            .join("results")
            .join(format!("fig2_{model}_{split}_{method}.csv"));
        r.to_csv(&csv)?;
        results.push(r);
    }

    render_ascii(&results);
    println!(
        "\nCSV curves written to {}/results/fig2_{model}_{split}_*.csv",
        dir.display()
    );
    let wall_secs: f64 = results.iter().map(|r| r.wall_secs).sum();
    println!("{}", wall_clock_line(args, results.len(), wall_secs)?);
    Ok(())
}

/// Coarse terminal plot: accuracy (y) vs log-scaled cum bytes (x).
pub fn render_ascii(results: &[RunResult]) {
    const W: usize = 72;
    const H: usize = 18;
    let max_b = results
        .iter()
        .flat_map(|r| r.curve().last().map(|c| c.0))
        .max()
        .unwrap_or(1) as f64;
    let min_b = results
        .iter()
        .flat_map(|r| r.curve().first().map(|c| c.0))
        .min()
        .unwrap_or(1)
        .max(1) as f64;
    let max_a = results
        .iter()
        .map(|r| r.best_accuracy())
        .fold(0.0f64, f64::max)
        .max(1e-9);
    let mut grid = vec![vec![' '; W]; H];
    let marks = ['o', 'x', '+', '*'];
    for (ri, r) in results.iter().enumerate() {
        for (b, a) in r.curve() {
            let xf = ((b as f64).ln() - min_b.ln())
                / (max_b.ln() - min_b.ln()).max(1e-9);
            let x = ((W - 1) as f64 * xf).round() as usize;
            let y = ((H - 1) as f64 * (1.0 - a / max_a)).round() as usize;
            grid[y.min(H - 1)][x.min(W - 1)] = marks[ri % marks.len()];
        }
    }
    println!(
        "\nFigure 2 — accuracy vs communication (log bytes) \
         [o=fp32 x=bq +=uq *=uq+]"
    );
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{:5.2}", max_a)
        } else if i == H - 1 {
            "0.00 ".into()
        } else {
            "     ".into()
        };
        println!("{label}|{}", row.iter().collect::<String>());
    }
    println!(
        "     +{}",
        "-".repeat(W)
    );
    println!(
        "      {:.1} KiB {: >60.1} MiB",
        min_b / 1024.0,
        max_b / (1 << 20) as f64
    );
}
