//! # fedfp8 — FP8FedAvg-UQ
//!
//! Reproduction of *"Towards Federated Learning with On-device Training
//! and Communication in 8-bit Floating Point"* (Wang, Berg, Acar, Zhou,
//! 2024) as a three-layer Rust + JAX + Pallas system.
//!
//! This crate is **Layer 3**: the federated coordinator. It owns the
//! round loop, client sampling, the *physical* 8-bit wire format
//! ([`fp8`]), the synthetic data substrate ([`data`]), aggregation and
//! ServerOptimize ([`coordinator`]), and the PJRT runtime that executes
//! the AOT-compiled JAX/Pallas compute graphs ([`runtime`]). Python
//! never runs at request time — `make artifacts` lowers the L2/L1
//! graphs to HLO text once, and this crate loads them.
//!
//! ```text
//! server (FP32 master) ──Q_rand──► 8-bit downlink ──► clients
//!    ▲                                              local FP8-QAT
//!    └── FedAvg / ServerOptimize ◄── 8-bit uplink ◄──┘   (U steps)
//! ```

pub mod bench_tables;
pub mod config;
pub mod coordinator;
pub mod daemon;
pub mod data;
pub mod fp8;
pub mod net;
pub mod runtime;
pub mod util;

pub use config::ExperimentConfig;
pub use coordinator::{RoundRecord, RunResult, Server};
