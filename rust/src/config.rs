//! Experiment configuration + named presets for every paper table row.
//!
//! A config fully determines a run: model variant, data generator,
//! client split, FL hyperparameters, quantizer switches and the
//! ServerOptimize settings. The Table-2 ablation grid and the Figure-2
//! method family are all *config switches* on the same coordinator —
//! no code forks (DESIGN.md §7).

use std::path::PathBuf;

use anyhow::{bail, ensure, Context, Result};

use crate::fp8::simd::KernelKind;
use crate::fp8::Rounding;
use crate::net::Inflight;
use crate::util::cli::Args;
use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub enum SplitCfg {
    Iid,
    /// Dirichlet label skew with the given concentration (paper: 0.3).
    Dirichlet(f64),
    /// One client per synthetic speaker (speech tasks).
    Speaker,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QatMode {
    /// Deterministic FP8 QAT (the paper's training default).
    Det,
    /// Stochastic FP8 QAT (Table 2 ablation arm).
    Rand,
    /// No quantization: FP32 baseline.
    None,
}

impl QatMode {
    pub fn artifact_suffix(&self) -> &'static str {
        match self {
            QatMode::Det => "det",
            QatMode::Rand => "rand",
            QatMode::None => "none",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LrSchedule {
    Const,
    /// Cosine decay over rounds to `final_frac * lr` (speech setup).
    Cosine { final_frac: f32 },
}

impl LrSchedule {
    pub fn lr_at(&self, base: f32, round: usize, total: usize) -> f32 {
        match self {
            LrSchedule::Const => base,
            LrSchedule::Cosine { final_frac } => {
                let t = round as f32 / total.max(1) as f32;
                let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
                base * (final_frac + (1.0 - final_frac) * cos)
            }
        }
    }
}

/// ServerOptimize (UQ+) settings — Eq. (4) GD steps + Eq. (5) grid.
#[derive(Clone, Debug, PartialEq)]
pub struct ServerOptCfg {
    pub gd_steps: usize,
    pub gd_lr: f32,
    pub grid_points: usize,
}

impl Default for ServerOptCfg {
    fn default() -> Self {
        // paper §4: 5 GD steps, lr grid-searched in {0.01,0.1,1},
        // 50 grid points for alpha
        Self {
            gd_steps: 5,
            gd_lr: 0.1,
            grid_points: 50,
        }
    }
}

/// Round-aggregation topology (`--agg flat|tree:G`).
///
/// Purely a throughput/topology knob: tree aggregation is bit-exact
/// against the flat stream by the canonical pairwise contract
/// (`coordinator::aggregate`, pinned by tests/tree_determinism.rs),
/// so like `parallelism` it is excluded from the config fingerprint.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum AggMode {
    /// One ordered FedAvg stream at the root (the default).
    #[default]
    Flat,
    /// Depth-2 tree: `nodes` mid-tier aggregators each fold a
    /// contiguous cohort shard and forward one weighted partial.
    Tree { nodes: usize },
}

impl std::fmt::Display for AggMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AggMode::Flat => write!(f, "flat"),
            AggMode::Tree { nodes } => write!(f, "tree:{nodes}"),
        }
    }
}

impl std::str::FromStr for AggMode {
    type Err = ConfigError;

    fn from_str(s: &str) -> Result<AggMode, ConfigError> {
        if s == "flat" {
            return Ok(AggMode::Flat);
        }
        if let Some(g) = s.strip_prefix("tree:") {
            if let Ok(nodes) = g.parse::<usize>() {
                if nodes >= 1 {
                    return Ok(AggMode::Tree { nodes });
                }
            }
        }
        Err(ConfigError::BadAggMode { spec: s.to_string() })
    }
}

/// Typed validation failures for the scale knobs (cohort size,
/// aggregation topology). Carried as `std::error::Error`, so they
/// travel through `anyhow::Result` while staying matchable in tests.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ConfigError {
    /// K = 0: no population to sample from.
    NoClients,
    /// Cohort (participation) of zero.
    CohortZero,
    /// Cohort exceeds the client population — previously a silent
    /// hand-built-config hazard, now rejected before any round runs.
    CohortExceedsPopulation { cohort: usize, clients: usize },
    /// `--cohort-frac` outside (0, 1].
    CohortFracOutOfRange { frac_bits: u32 },
    /// Two flags steering the same knob.
    FlagConflict {
        a: &'static str,
        b: &'static str,
    },
    /// Unparseable `--agg` spec (wants `flat` or `tree:G`, G >= 1).
    BadAggMode { spec: String },
    /// ServerOptimize needs every per-client vector at the root;
    /// retention cannot cross a tree link.
    TreeWithServerOpt,
    /// A snapshot knob (`--resume`, `--snapshot-every`) without
    /// `--snapshot-dir`: there is no directory to read or write.
    SnapshotFlagWithoutDir { flag: &'static str },
    /// `--snapshot-every 0` would never write a snapshot; asking for
    /// durability and never getting it must not parse.
    SnapshotEveryZero,
    /// Snapshot flags on `--role worker`: only the coordinator holds
    /// durable round state (workers are stateless between jobs save
    /// for their reconnect outcome cache).
    SnapshotOnWorker { flag: &'static str },
    /// A daemon knob (`--queue-dir`, `--daemon-slots`) without
    /// `--role daemon`: a forgotten role must not silently degrade a
    /// daemon launch into a plain local run.
    DaemonFlagWithoutRole { flag: &'static str },
    /// `--role daemon` without `--queue-dir`: a scheduler with no
    /// queue directory has nothing to run.
    DaemonWithoutQueueDir,
    /// `--daemon-slots 0` would never start a job; asking for a
    /// scheduler that never schedules must not parse.
    DaemonSlotsZero,
    /// `--telemetry-listen` on `--role worker`: only processes that
    /// drive the round loop (local runs, the coordinator, the daemon)
    /// emit round/run events.
    TelemetryOnWorker,
    /// `--net-aimd-spike` below 2: a spike multiplier under 2x would
    /// halve the adaptive window on ordinary latency jitter.
    AimdSpikeTooSmall { got: u32 },
    /// `--net-aimd-cap 0` would never let a connection carry a job.
    AimdCapZero,
    /// Unparseable `--shard` spec (wants `i/G` with 0 <= i < G).
    BadShardSpec { spec: String },
    /// `--shard` on a role that never executes a cohort shard: only
    /// mid-tier aggregators pin their shard index.
    ShardWithoutAggregator,
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::NoClients => {
                write!(f, "clients must be at least 1")
            }
            ConfigError::CohortZero => {
                write!(f, "cohort (participation) must be at least 1")
            }
            ConfigError::CohortExceedsPopulation { cohort, clients } => {
                write!(
                    f,
                    "cohort {cohort} exceeds the client population \
                     {clients}"
                )
            }
            ConfigError::CohortFracOutOfRange { frac_bits } => {
                write!(
                    f,
                    "--cohort-frac {} must be in (0, 1]",
                    f32::from_bits(*frac_bits)
                )
            }
            ConfigError::FlagConflict { a, b } => {
                write!(f, "--{a} conflicts with --{b}: pass only one")
            }
            ConfigError::BadAggMode { spec } => {
                write!(
                    f,
                    "bad --agg '{spec}' (expected flat or tree:G \
                     with G >= 1)"
                )
            }
            ConfigError::TreeWithServerOpt => {
                write!(
                    f,
                    "--agg tree is incompatible with ServerOptimize \
                     (uq+): per-client vectors cannot cross a tree \
                     link"
                )
            }
            ConfigError::SnapshotFlagWithoutDir { flag } => {
                write!(
                    f,
                    "--{flag} requires --snapshot-dir DIR (no \
                     snapshot directory to use)"
                )
            }
            ConfigError::SnapshotEveryZero => {
                write!(
                    f,
                    "--snapshot-every must be at least 1 (0 would \
                     never write a snapshot)"
                )
            }
            ConfigError::SnapshotOnWorker { flag } => {
                write!(
                    f,
                    "--{flag} only applies to the coordinator; \
                     worker and aggregator roles hold no durable \
                     round state"
                )
            }
            ConfigError::DaemonFlagWithoutRole { flag } => {
                write!(
                    f,
                    "--{flag} only makes sense with --role daemon"
                )
            }
            ConfigError::DaemonWithoutQueueDir => {
                write!(
                    f,
                    "--role daemon requires --queue-dir DIR (no job \
                     queue to schedule)"
                )
            }
            ConfigError::DaemonSlotsZero => {
                write!(
                    f,
                    "--daemon-slots must be at least 1 (0 would \
                     never start a job)"
                )
            }
            ConfigError::TelemetryOnWorker => {
                write!(
                    f,
                    "--telemetry-listen only applies to processes \
                     that drive the round loop; worker and \
                     aggregator roles never emit telemetry"
                )
            }
            ConfigError::AimdSpikeTooSmall { got } => {
                write!(
                    f,
                    "--net-aimd-spike must be at least 2 (got \
                     {got}): a spike threshold under 2x would halve \
                     the window on ordinary latency jitter"
                )
            }
            ConfigError::AimdCapZero => {
                write!(
                    f,
                    "--net-aimd-cap must be at least 1 (a zero cap \
                     would never let a connection carry a job)"
                )
            }
            ConfigError::BadShardSpec { spec } => {
                write!(
                    f,
                    "bad --shard '{spec}' (expected i/G with \
                     0 <= i < G, e.g. --shard 0/2)"
                )
            }
            ConfigError::ShardWithoutAggregator => {
                write!(
                    f,
                    "--shard only applies to --role aggregator \
                     (the mid-tier role that owns a cohort shard)"
                )
            }
        }
    }
}

impl std::error::Error for ConfigError {}

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    /// Manifest model-variant name (e.g. "lenet_c10").
    pub model: String,
    pub split: SplitCfg,
    /// K — total client count.
    pub clients: usize,
    /// P — participating clients per round (must equal the artifact's
    /// baked `server_p` when ServerOptimize is enabled).
    pub participation: usize,
    pub rounds: usize,
    pub lr: f32,
    pub weight_decay: f32,
    pub schedule: LrSchedule,
    /// QAT quantizer during local training.
    pub qat: QatMode,
    /// Communication quantizer (uplink + downlink).
    pub comm: Rounding,
    pub server_opt: Option<ServerOptCfg>,
    pub eval_every: usize,
    pub seed: u64,
    pub n_train: usize,
    pub n_test: usize,
    /// Synthetic speakers (speech tasks).
    pub speakers: usize,
    pub flip_aug: bool,
    /// Extension (paper Remark 3): error-feedback memory on both
    /// links, making *biased* communication viable (EF à la
    /// Richtárik et al.; the paper cites EF21 as the fix for BQ).
    pub error_feedback: bool,
    /// Extension (paper §5 future work): fraction of clients training
    /// in full FP32 (heterogeneous hardware fleets); all clients still
    /// communicate through the configured wire quantizer.
    pub fp32_client_frac: f32,
    /// Worker threads for the per-round client fan-out (the cohort is
    /// embarrassingly parallel). Results are bit-identical for every
    /// value — per-client RNG streams are counter-derived and
    /// aggregation applies uplinks in cohort order — so this is purely
    /// a wall-clock knob. 1 = sequential (no threads spawned).
    pub parallelism: usize,
    /// FP8 quantize/encode kernel (`--fp8-kernel scalar|simd|auto`).
    /// Every kernel is bit-identical to the scalar oracle (enforced
    /// by the exhaustive conformance harness), so like `parallelism`
    /// this is purely a wall-clock knob.
    pub fp8_kernel: KernelKind,
    /// Round-aggregation topology (`--agg flat|tree:G`). Bit-exact
    /// against flat for every fan-out, so also a pure wall-clock knob.
    pub agg: AggMode,
}

impl ExperimentConfig {
    /// Base config per model variant (scaled-down counterpart of the
    /// paper's §4 setup; see DESIGN.md §Substitutions for the mapping).
    pub fn base(model: &str) -> Result<ExperimentConfig> {
        let vision = ExperimentConfig {
            name: String::new(),
            model: model.to_string(),
            split: SplitCfg::Iid,
            clients: 40,
            participation: 10,
            rounds: 60,
            lr: 0.1,
            weight_decay: 1e-3,
            schedule: LrSchedule::Const,
            qat: QatMode::Det,
            comm: Rounding::Stochastic,
            server_opt: None,
            eval_every: 2,
            seed: 1,
            n_train: 4000,
            n_test: 1024,
            speakers: 0,
            flip_aug: true,
            error_feedback: false,
            fp32_client_frac: 0.0,
            parallelism: 1,
            fp8_kernel: KernelKind::Auto,
            agg: AggMode::Flat,
        };
        Ok(match model {
            "mlp_c10" | "lenet_c10" | "lenet_c100" | "resnet8_c10"
            | "resnet8_c100" => vision,
            "matchbox" | "kwt" => ExperimentConfig {
                clients: 64,
                participation: 8,
                rounds: 50,
                lr: 1e-3,
                weight_decay: 0.1,
                schedule: LrSchedule::Cosine { final_frac: 0.05 },
                split: SplitCfg::Speaker,
                n_train: 3200,
                n_test: 768,
                speakers: 64,
                flip_aug: false,
                ..vision
            },
            _ => bail!("unknown model variant '{model}'"),
        })
    }

    /// Apply a named method arm (the Figure-2 family / Table columns).
    pub fn with_method(mut self, method: &str) -> Result<ExperimentConfig> {
        match method {
            // FP32 FedAvg baseline
            "fp32" => {
                self.qat = QatMode::None;
                self.comm = Rounding::None;
                self.server_opt = None;
            }
            // FP8FedAvg-UQ (paper's main method)
            "uq" => {
                self.qat = QatMode::Det;
                self.comm = Rounding::Stochastic;
                self.server_opt = None;
            }
            // FP8FedAvg-UQ+ (with ServerOptimize)
            "uq+" => {
                self.qat = QatMode::Det;
                self.comm = Rounding::Stochastic;
                self.server_opt = Some(ServerOptCfg::default());
            }
            // biased communication ablation (Fig. 2 "BQ", Table 2 det CQ)
            "bq" => {
                self.qat = QatMode::Det;
                self.comm = Rounding::Deterministic;
                self.server_opt = None;
            }
            // Table 2: stochastic QAT with (rand) CQ
            "randqat" => {
                self.qat = QatMode::Rand;
                self.comm = Rounding::Stochastic;
                self.server_opt = None;
            }
            // Table 2: FP8 QAT without communication quantization
            "nocq_det" => {
                self.qat = QatMode::Det;
                self.comm = Rounding::None;
                self.server_opt = None;
            }
            "nocq_rand" => {
                self.qat = QatMode::Rand;
                self.comm = Rounding::None;
                self.server_opt = None;
            }
            // extension: biased CQ rescued by error feedback
            "bq_ef" => {
                self.qat = QatMode::Det;
                self.comm = Rounding::Deterministic;
                self.server_opt = None;
                self.error_feedback = true;
            }
            // extension: half the fleet trains in FP32 (heterogeneous
            // hardware), everyone communicates in FP8-UQ
            "mixed" => {
                self.qat = QatMode::Det;
                self.comm = Rounding::Stochastic;
                self.server_opt = None;
                self.fp32_client_frac = 0.5;
            }
            _ => bail!(
                "unknown method '{method}' (fp32|uq|uq+|bq|randqat|\
                 nocq_det|nocq_rand|bq_ef|mixed)"
            ),
        }
        self.name = format!("{}_{}", self.model, method);
        Ok(self)
    }

    pub fn with_split(mut self, split: &str) -> Result<ExperimentConfig> {
        self.split = match split {
            "iid" => SplitCfg::Iid,
            "dir03" => SplitCfg::Dirichlet(0.3),
            "speaker" => SplitCfg::Speaker,
            _ => bail!("unknown split '{split}' (iid|dir03|speaker)"),
        };
        if !self.name.is_empty() {
            self.name = format!("{}_{}", self.name, split);
        }
        Ok(self)
    }

    /// Parse "model:method:split" preset notation.
    pub fn preset(spec: &str) -> Result<ExperimentConfig> {
        let parts: Vec<&str> = spec.split(':').collect();
        match parts.as_slice() {
            [model, method, split] => Self::base(model)?
                .with_method(method)?
                .with_split(split),
            [model, method] => Self::base(model)?.with_method(method),
            _ => bail!("preset must be model:method[:split], got '{spec}'"),
        }
    }

    /// Uplink+downlink payload cost is FP32 iff comm == None.
    pub fn is_fp32_comm(&self) -> bool {
        self.comm == Rounding::None
    }

    /// Validate the scale knobs: cohort vs population, aggregation
    /// topology. Called by `Server::with_transport` (so a hand-built
    /// config cannot silently sample beyond the population) and by the
    /// CLI after all overrides are applied.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.clients == 0 {
            return Err(ConfigError::NoClients);
        }
        if self.participation == 0 {
            return Err(ConfigError::CohortZero);
        }
        if self.participation > self.clients {
            return Err(ConfigError::CohortExceedsPopulation {
                cohort: self.participation,
                clients: self.clients,
            });
        }
        if let AggMode::Tree { nodes } = self.agg {
            if nodes == 0 {
                return Err(ConfigError::BadAggMode {
                    spec: "tree:0".to_string(),
                });
            }
            if self.server_opt.is_some() {
                return Err(ConfigError::TreeWithServerOpt);
            }
        }
        Ok(())
    }

    /// Apply the scale flags — `--cohort P` / `--cohort-frac f` /
    /// `--agg flat|tree:G` — with the same orphan/conflict guards the
    /// networked flags use, then [`validate`](Self::validate) the
    /// result. `--cohort` is an alias for `--participation` in the
    /// paper's P-of-K notation; `--cohort-frac` scales off the (final)
    /// client count, so apply it after any `--clients` override.
    pub fn apply_scale_flags(&mut self, args: &Args) -> Result<()> {
        for (a, b) in [
            ("cohort", "cohort-frac"),
            ("cohort", "participation"),
            ("cohort-frac", "participation"),
        ] {
            if args.get(a).is_some() && args.get(b).is_some() {
                return Err(ConfigError::FlagConflict { a, b }.into());
            }
        }
        if args.get("cohort").is_some() {
            self.participation =
                args.parse_or("cohort", self.participation)?;
        }
        if args.get("cohort-frac").is_some() {
            let frac: f32 = args.parse_or("cohort-frac", 1.0)?;
            if !(frac > 0.0 && frac <= 1.0) {
                return Err(ConfigError::CohortFracOutOfRange {
                    frac_bits: frac.to_bits(),
                }
                .into());
            }
            self.participation = ((self.clients as f64 * frac as f64)
                .round() as usize)
                .max(1);
        }
        if let Some(spec) = args.get("agg") {
            self.agg = spec.parse::<AggMode>()?;
        }
        self.validate()?;
        Ok(())
    }

    /// Stable 64-bit fingerprint of every field that determines the
    /// federated trajectory — the handshake token of the networked
    /// transport: a server only accepts workers whose config hashes
    /// identically, because both sides independently rebuild the
    /// world (data, shards, schedules) from their own config copy.
    ///
    /// Deliberately excluded: `parallelism` and `fp8_kernel` (per-host
    /// wall-clock knobs that never change results — the determinism
    /// and kernel-exactness contracts; a server pinned to the scalar
    /// kernel happily drives AVX2 workers and vice versa) and `name`
    /// (derived from model/method/split). Floats hash by
    /// bit pattern. FNV-1a over a canonical field rendering; the
    /// rendering includes field tags, so reordering or retyping a
    /// field changes the hash even when raw bytes would collide.
    pub fn fingerprint(&self) -> u64 {
        // exhaustive destructure: adding a config field without
        // deciding its fingerprint fate is a compile error, so a new
        // trajectory knob can never silently pass the handshake
        let ExperimentConfig {
            name: _,
            model,
            split,
            clients,
            participation,
            rounds,
            lr,
            weight_decay,
            schedule,
            qat,
            comm,
            server_opt,
            eval_every,
            seed,
            n_train,
            n_test,
            speakers,
            flip_aug,
            error_feedback,
            fp32_client_frac,
            parallelism: _,
            fp8_kernel: _,
            // bit-exact against flat at every fan-out (the tree-vs-
            // flat contract), so a flat server drives tree-mode
            // workers' worlds identically — excluded like parallelism
            agg: _,
        } = self;
        let split = match split {
            SplitCfg::Iid => "iid".to_string(),
            SplitCfg::Dirichlet(c) => {
                format!("dir:{:016x}", c.to_bits())
            }
            SplitCfg::Speaker => "speaker".to_string(),
        };
        let sched = match schedule {
            LrSchedule::Const => "const".to_string(),
            LrSchedule::Cosine { final_frac } => {
                format!("cos:{:08x}", final_frac.to_bits())
            }
        };
        let sopt = match server_opt {
            None => "none".to_string(),
            Some(s) => format!(
                "gd{}:{:08x}:g{}",
                s.gd_steps,
                s.gd_lr.to_bits(),
                s.grid_points
            ),
        };
        let repr = format!(
            "model={model};split={split};clients={clients};\
             participation={participation};rounds={rounds};\
             lr={:08x};wd={:08x};sched={sched};qat={qat:?};\
             comm={comm:?};sopt={sopt};seed={seed};\
             eval_every={eval_every};n_train={n_train};\
             n_test={n_test};speakers={speakers};flip={flip_aug};\
             ef={error_feedback};fp32frac={:08x}",
            lr.to_bits(),
            weight_decay.to_bits(),
            fp32_client_frac.to_bits(),
        );
        let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a 64 offset basis
        for &b in repr.as_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    /// Serialize to the canonical JSON object the daemon job queue
    /// consumes (`daemon::queue`). Exhaustive destructure, mirroring
    /// [`fingerprint`](Self::fingerprint): adding a config field
    /// without deciding its JSON encoding is a compile error.
    ///
    /// f32 fields survive the trip bit-exactly: the serializer prints
    /// the shortest f64 roundtrip, and every f32 widens to f64
    /// losslessly. The seed is a JSON number while it is exactly
    /// representable as an f64 integer (< 2^53) and a decimal string
    /// beyond that; [`from_json`](Self::from_json) accepts both.
    pub fn to_json(&self) -> Json {
        use std::collections::BTreeMap;

        let ExperimentConfig {
            name,
            model,
            split,
            clients,
            participation,
            rounds,
            lr,
            weight_decay,
            schedule,
            qat,
            comm,
            server_opt,
            eval_every,
            seed,
            n_train,
            n_test,
            speakers,
            flip_aug,
            error_feedback,
            fp32_client_frac,
            parallelism,
            fp8_kernel,
            agg,
        } = self;
        let num = |n: usize| Json::Num(n as f64);
        let split = match split {
            SplitCfg::Iid => Json::Str("iid".into()),
            SplitCfg::Speaker => Json::Str("speaker".into()),
            SplitCfg::Dirichlet(c) => Json::Obj(BTreeMap::from([(
                "dirichlet".to_string(),
                Json::Num(*c),
            )])),
        };
        let schedule = match schedule {
            LrSchedule::Const => Json::Str("const".into()),
            LrSchedule::Cosine { final_frac } => {
                Json::Obj(BTreeMap::from([(
                    "cosine_final_frac".to_string(),
                    Json::Num(*final_frac as f64),
                )]))
            }
        };
        let qat = Json::Str(qat.artifact_suffix().into());
        let comm = Json::Str(
            match comm {
                Rounding::Stochastic => "stochastic",
                Rounding::Deterministic => "deterministic",
                Rounding::None => "none",
            }
            .into(),
        );
        let server_opt = match server_opt {
            None => Json::Null,
            Some(s) => Json::Obj(BTreeMap::from([
                ("gd_steps".to_string(), num(s.gd_steps)),
                ("gd_lr".to_string(), Json::Num(s.gd_lr as f64)),
                ("grid_points".to_string(), num(s.grid_points)),
            ])),
        };
        let seed = if *seed < (1u64 << 53) {
            Json::Num(*seed as f64)
        } else {
            Json::Str(seed.to_string())
        };
        let mut m = BTreeMap::new();
        for (k, v) in [
            ("name", Json::Str(name.clone())),
            ("model", Json::Str(model.clone())),
            ("split", split),
            ("clients", num(*clients)),
            ("participation", num(*participation)),
            ("rounds", num(*rounds)),
            ("lr", Json::Num(*lr as f64)),
            ("weight_decay", Json::Num(*weight_decay as f64)),
            ("schedule", schedule),
            ("qat", qat),
            ("comm", comm),
            ("server_opt", server_opt),
            ("eval_every", num(*eval_every)),
            ("seed", seed),
            ("n_train", num(*n_train)),
            ("n_test", num(*n_test)),
            ("speakers", num(*speakers)),
            ("flip_aug", Json::Bool(*flip_aug)),
            ("error_feedback", Json::Bool(*error_feedback)),
            (
                "fp32_client_frac",
                Json::Num(*fp32_client_frac as f64),
            ),
            ("parallelism", num(*parallelism)),
            ("fp8_kernel", Json::Str(fp8_kernel.to_string())),
            ("agg", Json::Str(agg.to_string())),
        ] {
            m.insert(k.to_string(), v);
        }
        Json::Obj(m)
    }

    /// Build a config from a JSON job spec. Only `model` is required:
    /// the spec starts from [`base`](Self::base) (optionally routed
    /// through [`with_method`](Self::with_method) when a `method` key
    /// is present), then every present field overrides the default —
    /// so a hand-written three-line spec and a full
    /// [`to_json`](Self::to_json) dump both parse, and the result is
    /// always [`validate`](Self::validate)d.
    pub fn from_json(v: &Json) -> Result<ExperimentConfig> {
        let model = v
            .get("model")
            .context("job spec: missing 'model'")?
            .as_str()?;
        let mut c = ExperimentConfig::base(model)?;
        if let Some(m) = v.opt("method") {
            c = c.with_method(m.as_str()?)?;
        }
        if let Some(s) = v.opt("split") {
            c.split = match s {
                Json::Str(t) if t == "iid" => SplitCfg::Iid,
                Json::Str(t) if t == "speaker" => SplitCfg::Speaker,
                Json::Obj(_) => SplitCfg::Dirichlet(
                    s.get("dirichlet")?.as_f64()?,
                ),
                _ => bail!(
                    "bad 'split' (\"iid\" | \"speaker\" | \
                     {{\"dirichlet\": c}})"
                ),
            };
        }
        for (key, slot) in [
            ("clients", &mut c.clients),
            ("participation", &mut c.participation),
            ("rounds", &mut c.rounds),
            ("eval_every", &mut c.eval_every),
            ("n_train", &mut c.n_train),
            ("n_test", &mut c.n_test),
            ("speakers", &mut c.speakers),
            ("parallelism", &mut c.parallelism),
        ] {
            if let Some(n) = v.opt(key) {
                *slot = n
                    .as_usize()
                    .with_context(|| format!("job spec: '{key}'"))?;
            }
        }
        for (key, slot) in [
            ("lr", &mut c.lr),
            ("weight_decay", &mut c.weight_decay),
            ("fp32_client_frac", &mut c.fp32_client_frac),
        ] {
            if let Some(n) = v.opt(key) {
                *slot = n
                    .as_f64()
                    .with_context(|| format!("job spec: '{key}'"))?
                    as f32;
            }
        }
        for (key, slot) in [
            ("flip_aug", &mut c.flip_aug),
            ("error_feedback", &mut c.error_feedback),
        ] {
            if let Some(b) = v.opt(key) {
                *slot = b
                    .as_bool()
                    .with_context(|| format!("job spec: '{key}'"))?;
            }
        }
        if let Some(s) = v.opt("schedule") {
            c.schedule = match s {
                Json::Str(t) if t == "const" => LrSchedule::Const,
                Json::Obj(_) => LrSchedule::Cosine {
                    final_frac: s.get("cosine_final_frac")?.as_f64()?
                        as f32,
                },
                _ => bail!(
                    "bad 'schedule' (\"const\" | \
                     {{\"cosine_final_frac\": f}})"
                ),
            };
        }
        if let Some(q) = v.opt("qat") {
            c.qat = match q.as_str()? {
                "det" => QatMode::Det,
                "rand" => QatMode::Rand,
                "none" => QatMode::None,
                other => {
                    bail!("bad 'qat' '{other}' (det|rand|none)")
                }
            };
        }
        if let Some(q) = v.opt("comm") {
            c.comm = match q.as_str()? {
                "stochastic" => Rounding::Stochastic,
                "deterministic" => Rounding::Deterministic,
                "none" => Rounding::None,
                other => bail!(
                    "bad 'comm' '{other}' \
                     (stochastic|deterministic|none)"
                ),
            };
        }
        // `opt` filters Null, so an explicit `"server_opt": null`
        // keeps the default (None unless a method arm set it)
        if let Some(s) = v.opt("server_opt") {
            c.server_opt = Some(ServerOptCfg {
                gd_steps: s.get("gd_steps")?.as_usize()?,
                gd_lr: s.get("gd_lr")?.as_f64()? as f32,
                grid_points: s.get("grid_points")?.as_usize()?,
            });
        }
        if let Some(s) = v.opt("seed") {
            c.seed = match s {
                Json::Num(n)
                    if *n >= 0.0
                        && n.fract() == 0.0
                        && *n < (1u64 << 53) as f64 =>
                {
                    *n as u64
                }
                Json::Str(t) => t
                    .parse::<u64>()
                    .context("job spec: 'seed' string")?,
                _ => bail!(
                    "bad 'seed' (non-negative integer, or a decimal \
                     string for values at or above 2^53)"
                ),
            };
        }
        if let Some(k) = v.opt("fp8_kernel") {
            c.fp8_kernel = k
                .as_str()?
                .parse::<KernelKind>()
                .map_err(|e| anyhow::anyhow!(e))?;
        }
        if let Some(a) = v.opt("agg") {
            c.agg = a.as_str()?.parse::<AggMode>()?;
        }
        if let Some(n) = v.opt("name") {
            c.name = n.as_str()?.to_string();
        } else if c.name.is_empty() {
            // hand-written specs without a method arm still need a
            // job label for telemetry events
            c.name = model.to_string();
        }
        c.validate()?;
        Ok(c)
    }
}

/// Which end of the networked transport this process plays.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetRole {
    /// Coordinator: binds, accepts workers, drives the round loop.
    Server,
    /// Client executor: connects and serves jobs until shutdown.
    Worker,
    /// Mid-tier tree node: connects upstream to the root, listens
    /// downstream for its own workers, executes cohort shards and
    /// forwards one `FrameKind::Partial` per round.
    Aggregator,
}

/// Networked-run settings parsed from the CLI
/// (`--role server --listen ADDR` / `--role worker --connect ADDR`).
#[derive(Clone, Debug)]
pub struct NetCfg {
    pub role: NetRole,
    /// Listen address (server) or upstream address (worker and
    /// aggregator `--connect`).
    pub addr: String,
    /// Downstream listen address — aggregator only (`--listen` on
    /// `--role aggregator`); the server's listen address is `addr`.
    pub listen: Option<String>,
    /// `--shard i/G` (aggregator only): pin this process to cohort
    /// shard `i` of a `tree:G` root. `None` lets the root assign
    /// shards in connection order.
    pub shard: Option<(u32, u32)>,
    /// Worker connections the server waits for before round 0.
    pub workers: usize,
    /// Socket read/write deadline (and handshake deadline), plus the
    /// idle deadline after which a silent peer is declared dead —
    /// the "never hang" bound.
    pub timeout_ms: u64,
    /// `--net-inflight N|adaptive`: sliding window of concurrently
    /// in-flight jobs per worker connection (server side), and the
    /// worker's executor-pool width hint (worker side). 1 = v1-style
    /// lockstep; `adaptive` grows each connection's window from its
    /// observed outcome latency.
    pub inflight: Inflight,
    /// `--heartbeat-ms T`: probe a silent connection after T ms of
    /// quiet, on both sides; 0 disables heartbeats (a silent
    /// partition is then only detected while jobs are pending).
    /// Defaults to `min(1000, timeout/4)` so the probe-before-deadline
    /// invariant holds at any `--net-timeout-ms`.
    pub heartbeat_ms: u64,
    /// `--net-hedge-ms T` (server only): duplicate a job onto a
    /// second worker after it has gone unanswered this long — tail
    /// latency insurance for stragglers; first answer wins, results
    /// stay bit-identical. 0 disables hedging.
    pub hedge_ms: u64,
    /// `--net-token SECRET`: shared handshake token. Both sides
    /// carry an FNV-1a digest of it in Hello/HelloAck and reject a
    /// peer whose digest differs (typed `WireError::AuthRejected`).
    /// This fences off misconfigured or foreign processes — never
    /// expose a listener beyond localhost without it. It is *not*
    /// cryptographic transport security; TLS is the ROADMAP item
    /// for hostile networks.
    pub token: Option<String>,
    /// `--net-aimd-spike S` (dispatching roles): an outcome whose
    /// latency exceeds S times the connection's EWMA halves the
    /// adaptive window (multiplicative decrease). Must be >= 2;
    /// default 4 — the historical hard-coded constant.
    pub aimd_spike: u32,
    /// `--net-aimd-cap N` (dispatching roles): upper bound on the
    /// adaptive window's additive growth. Must be >= 1; default 32 —
    /// the historical hard-coded constant.
    pub aimd_cap: usize,
}

/// Parse a `--shard i/G` spec into `(i, G)` with `0 <= i < G`.
fn parse_shard(spec: &str) -> Result<(u32, u32), ConfigError> {
    let bad = || ConfigError::BadShardSpec {
        spec: spec.to_string(),
    };
    let (i, g) = spec.split_once('/').ok_or_else(bad)?;
    let i: u32 = i.parse().map_err(|_| bad())?;
    let g: u32 = g.parse().map_err(|_| bad())?;
    if g == 0 || i >= g {
        return Err(bad());
    }
    Ok((i, g))
}

impl NetCfg {
    /// Parse the networked-run flags; `Ok(None)` means a plain
    /// in-process run was requested.
    pub fn from_args(args: &Args) -> Result<Option<NetCfg>> {
        let Some(role) = args.get("role") else {
            // a forgotten --role must not silently degrade a
            // networked launch into a local run
            for flag in [
                "listen",
                "connect",
                "workers",
                "net-timeout-ms",
                "net-inflight",
                "heartbeat-ms",
                "net-hedge-ms",
                "net-token",
                "net-aimd-spike",
                "net-aimd-cap",
                "shard",
            ] {
                ensure!(
                    args.get(flag).is_none(),
                    "--{flag} only makes sense with \
                     --role server|worker|aggregator"
                );
            }
            return Ok(None);
        };
        let timeout_ms = args.parse_or("net-timeout-ms", 30_000u64)?;
        ensure!(timeout_ms > 0, "--net-timeout-ms must be positive");
        let inflight =
            args.parse_or("net-inflight", Inflight::Fixed(4))?;
        // derived default: the probe interval always fits inside the
        // idle deadline, however small --net-timeout-ms is (the old
        // fixed 1000 made any timeout <= 1000 a startup error)
        let heartbeat_ms = args
            .parse_or("heartbeat-ms", (timeout_ms / 4).min(1_000))?;
        let hedge_ms = args.parse_or("net-hedge-ms", 0u64)?;
        ensure!(
            hedge_ms == 0 || hedge_ms < timeout_ms,
            "--net-hedge-ms ({hedge_ms}) must be less than \
             --net-timeout-ms ({timeout_ms}), or 0 to disable hedging"
        );
        // AIMD knobs of the adaptive window (defaults unchanged from
        // the historical hard-coded constants: 4x spike, cap 32)
        let aimd_spike = args.parse_or("net-aimd-spike", 4u32)?;
        if aimd_spike < 2 {
            return Err(ConfigError::AimdSpikeTooSmall {
                got: aimd_spike,
            }
            .into());
        }
        let aimd_cap = args.parse_or("net-aimd-cap", 32usize)?;
        if aimd_cap == 0 {
            return Err(ConfigError::AimdCapZero.into());
        }
        let token = args.get("net-token").map(String::from);
        if let Some(t) = &token {
            ensure!(
                !t.is_empty(),
                "--net-token must not be empty (drop the flag to \
                 run without handshake auth)"
            );
        }
        // the probe interval must fit inside the idle deadline, or a
        // peer would be declared dead before it was ever probed
        ensure!(
            heartbeat_ms == 0 || heartbeat_ms < timeout_ms,
            "--heartbeat-ms ({heartbeat_ms}) must be less than \
             --net-timeout-ms ({timeout_ms}), or 0 to disable probing"
        );
        let shard = match args.get("shard") {
            Some(_) if role != "aggregator" => {
                return Err(ConfigError::ShardWithoutAggregator.into());
            }
            Some(spec) => Some(parse_shard(spec)?),
            None => None,
        };
        let cfg = match role {
            "server" => {
                ensure!(
                    args.get("connect").is_none(),
                    "--connect is a worker/aggregator flag; --role \
                     server listens (--listen ADDR)"
                );
                let addr = args
                    .required("listen", "--role server")
                    .context("e.g. --listen 127.0.0.1:7878")?;
                let workers = args.parse_or("workers", 1usize)?;
                ensure!(workers >= 1, "--workers must be at least 1");
                NetCfg {
                    role: NetRole::Server,
                    addr: addr.to_string(),
                    listen: None,
                    shard: None,
                    workers,
                    timeout_ms,
                    inflight,
                    heartbeat_ms,
                    hedge_ms,
                    token,
                    aimd_spike,
                    aimd_cap,
                }
            }
            "worker" => {
                ensure!(
                    args.get("listen").is_none(),
                    "--listen is a server/aggregator flag; --role \
                     worker connects (--connect ADDR)"
                );
                ensure!(
                    args.get("workers").is_none(),
                    "--workers only applies to roles that accept \
                     downstream connections (server, aggregator)"
                );
                ensure!(
                    args.get("net-hedge-ms").is_none(),
                    "--net-hedge-ms only applies to dispatching \
                     roles (the dispatcher decides when to hedge)"
                );
                for flag in ["net-aimd-spike", "net-aimd-cap"] {
                    ensure!(
                        args.get(flag).is_none(),
                        "--{flag} only applies to dispatching roles \
                         (server, aggregator): the window is the \
                         dispatcher's"
                    );
                }
                let addr = args
                    .required("connect", "--role worker")
                    .context("e.g. --connect 127.0.0.1:7878")?;
                NetCfg {
                    role: NetRole::Worker,
                    addr: addr.to_string(),
                    listen: None,
                    shard: None,
                    workers: 1,
                    timeout_ms,
                    inflight,
                    heartbeat_ms,
                    hedge_ms: 0,
                    token,
                    aimd_spike,
                    aimd_cap,
                }
            }
            "aggregator" => {
                let addr = args
                    .required("connect", "--role aggregator")
                    .context(
                        "the upstream root, e.g. \
                         --connect 127.0.0.1:7878",
                    )?;
                let listen = args
                    .required("listen", "--role aggregator")
                    .context(
                        "the downstream worker listener, e.g. \
                         --listen 127.0.0.1:7879",
                    )?;
                let workers = args.parse_or("workers", 1usize)?;
                ensure!(workers >= 1, "--workers must be at least 1");
                NetCfg {
                    role: NetRole::Aggregator,
                    addr: addr.to_string(),
                    listen: Some(listen.to_string()),
                    shard,
                    workers,
                    timeout_ms,
                    inflight,
                    heartbeat_ms,
                    hedge_ms,
                    token,
                    aimd_spike,
                    aimd_cap,
                }
            }
            other => {
                bail!(
                    "unknown --role '{other}' \
                     (server|worker|aggregator)"
                )
            }
        };
        Ok(Some(cfg))
    }
}

/// Durability settings parsed from the CLI (`--snapshot-dir DIR
/// [--snapshot-every N] [--resume]`).
///
/// Deliberately *not* part of [`ExperimentConfig`]: where and how
/// often state is persisted is an operational knob, like
/// `--parallelism` — it must never move the config fingerprint,
/// because the fingerprint is what gates resume (durability flags
/// shifting it would make every snapshot unresumable against the
/// very flags that wrote it).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SnapshotCfg {
    /// `--snapshot-dir DIR`: where generations live; `None` disables
    /// the durability layer entirely.
    pub dir: Option<PathBuf>,
    /// `--snapshot-every N`: write one generation every N completed
    /// rounds (default 1 — every round boundary is durable).
    pub every: usize,
    /// `--resume`: load the newest valid generation before the first
    /// round. A cold (empty) directory starts at round 0, so the
    /// flag is safe on the very first launch of a kill/resume loop.
    pub resume: bool,
}

impl SnapshotCfg {
    pub fn enabled(&self) -> bool {
        self.dir.is_some()
    }

    /// Parse the durability flags with typed guards: snapshot knobs
    /// without a directory, a zero cadence, and snapshot flags on a
    /// `--role worker` launch are all [`ConfigError`]s.
    pub fn from_args(
        args: &Args,
        net: Option<&NetCfg>,
    ) -> Result<SnapshotCfg> {
        let dir = args.get("snapshot-dir").map(PathBuf::from);
        let every_present = args.get("snapshot-every").is_some();
        // `--resume` is a bare flag, but the parser will treat
        // `--resume x` as an option — accept both spellings
        let resume =
            args.flag("resume") || args.get("resume").is_some();
        if matches!(
            net,
            Some(n) if matches!(
                n.role,
                NetRole::Worker | NetRole::Aggregator
            )
        ) {
            for (present, flag) in [
                (dir.is_some(), "snapshot-dir"),
                (every_present, "snapshot-every"),
                (resume, "resume"),
            ] {
                if present {
                    return Err(
                        ConfigError::SnapshotOnWorker { flag }.into()
                    );
                }
            }
        }
        if dir.is_none() {
            for (present, flag) in
                [(every_present, "snapshot-every"), (resume, "resume")]
            {
                if present {
                    return Err(ConfigError::SnapshotFlagWithoutDir {
                        flag,
                    }
                    .into());
                }
            }
        }
        let every = args.parse_or("snapshot-every", 1usize)?;
        if every == 0 {
            return Err(ConfigError::SnapshotEveryZero.into());
        }
        Ok(SnapshotCfg { dir, every, resume })
    }
}

/// Run-scheduler daemon settings (`--role daemon --queue-dir D
/// [--daemon-slots N]`).
///
/// Like [`SnapshotCfg`], deliberately *not* part of
/// [`ExperimentConfig`]: where job specs live and how many run at
/// once are operational knobs that must never move the config
/// fingerprint of the jobs being scheduled.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DaemonCfg {
    /// Directory scanned for `*.job.json` specs; per-job state files
    /// live next to them.
    pub queue_dir: PathBuf,
    /// Concurrent job slots (default 1 = strictly sequential, in
    /// filename order).
    pub slots: usize,
}

impl DaemonCfg {
    /// Parse the daemon flags; `Ok(None)` means no daemon role was
    /// requested. Daemon knobs without `--role daemon` are typed
    /// [`ConfigError`]s (the snapshot-flag orphan-guard idiom), so a
    /// forgotten role cannot silently degrade a daemon launch into a
    /// plain local run.
    pub fn from_args(args: &Args) -> Result<Option<DaemonCfg>> {
        if args.get("role") != Some("daemon") {
            for flag in ["queue-dir", "daemon-slots"] {
                if args.get(flag).is_some() {
                    return Err(
                        ConfigError::DaemonFlagWithoutRole { flag }
                            .into(),
                    );
                }
            }
            return Ok(None);
        }
        // the daemon schedules *local* runs; the networked-transport
        // flags belong to --role server|worker launches, and silently
        // ignoring them here would mask a mis-pasted command line
        for flag in [
            "listen",
            "connect",
            "workers",
            "net-timeout-ms",
            "net-inflight",
            "heartbeat-ms",
            "net-hedge-ms",
            "net-token",
            "net-aimd-spike",
            "net-aimd-cap",
            "shard",
        ] {
            ensure!(
                args.get(flag).is_none(),
                "--{flag} only makes sense with --role \
                 server|worker|aggregator, not --role daemon"
            );
        }
        // per-job snapshots live under --queue-dir (<id>.snaps/) and
        // every job is implicitly resumable; the global snapshot
        // flags would be silently ignored, so reject them
        for flag in ["snapshot-dir", "snapshot-every"] {
            ensure!(
                args.get(flag).is_none(),
                "--{flag} does not apply to --role daemon: each job \
                 snapshots under <queue-dir>/<id>.snaps/ and resumes \
                 automatically"
            );
        }
        let Some(dir) = args.get("queue-dir") else {
            return Err(ConfigError::DaemonWithoutQueueDir.into());
        };
        let slots = args.parse_or("daemon-slots", 1usize)?;
        if slots == 0 {
            return Err(ConfigError::DaemonSlotsZero.into());
        }
        Ok(Some(DaemonCfg {
            queue_dir: PathBuf::from(dir),
            slots,
        }))
    }
}

/// Parse `--telemetry-listen ADDR` — the NDJSON event feed socket.
///
/// Valid on a plain local run, a `--role server` coordinator and the
/// daemon (everything that drives `Server::run`); a worker never runs
/// the round loop, so the flag there is a typed [`ConfigError`].
pub fn telemetry_listen_from_args(
    args: &Args,
    net: Option<&NetCfg>,
) -> Result<Option<String>> {
    let Some(addr) = args.get("telemetry-listen") else {
        return Ok(None);
    };
    if matches!(
        net,
        Some(n) if matches!(
            n.role,
            NetRole::Worker | NetRole::Aggregator
        )
    ) {
        return Err(ConfigError::TelemetryOnWorker.into());
    }
    ensure!(
        !addr.is_empty(),
        "--telemetry-listen needs an ADDR (e.g. 127.0.0.1:7979)"
    );
    Ok(Some(addr.to_string()))
}

#[cfg(test)]
mod tests {
    use std::path::Path;

    use super::*;

    #[test]
    fn preset_roundtrip() {
        let c = ExperimentConfig::preset("lenet_c10:uq+:dir03").unwrap();
        assert_eq!(c.model, "lenet_c10");
        assert_eq!(c.qat, QatMode::Det);
        assert_eq!(c.comm, Rounding::Stochastic);
        assert!(c.server_opt.is_some());
        assert_eq!(c.split, SplitCfg::Dirichlet(0.3));
        assert_eq!(c.name, "lenet_c10_uq+_dir03");
    }

    #[test]
    fn fp32_preset_has_no_quant() {
        let c = ExperimentConfig::preset("resnet8_c10:fp32:iid").unwrap();
        assert_eq!(c.qat, QatMode::None);
        assert_eq!(c.comm, Rounding::None);
        assert!(c.is_fp32_comm());
    }

    #[test]
    fn speech_defaults() {
        let c = ExperimentConfig::preset("kwt:uq:speaker").unwrap();
        assert_eq!(c.split, SplitCfg::Speaker);
        assert!(matches!(c.schedule, LrSchedule::Cosine { .. }));
        assert_eq!(c.participation, 8);
    }

    #[test]
    fn parallelism_defaults_to_sequential() {
        let c = ExperimentConfig::preset("lenet_c10:uq:iid").unwrap();
        assert_eq!(c.parallelism, 1);
    }

    #[test]
    fn rejects_unknown() {
        assert!(ExperimentConfig::preset("nope:uq:iid").is_err());
        assert!(ExperimentConfig::preset("lenet_c10:nope:iid").is_err());
        assert!(ExperimentConfig::preset("lenet_c10:uq:nope").is_err());
    }

    #[test]
    fn cosine_schedule_decays() {
        let s = LrSchedule::Cosine { final_frac: 0.1 };
        let l0 = s.lr_at(1.0, 0, 100);
        let l50 = s.lr_at(1.0, 50, 100);
        let l100 = s.lr_at(1.0, 100, 100);
        assert!((l0 - 1.0).abs() < 1e-6);
        assert!(l50 < l0 && l100 < l50);
        assert!((l100 - 0.1).abs() < 1e-6);
    }

    #[test]
    fn fingerprint_tracks_trajectory_fields_only() {
        let a = ExperimentConfig::preset("lenet_c10:uq:iid").unwrap();
        let mut b = a.clone();
        assert_eq!(a.fingerprint(), b.fingerprint());
        // wall-clock knobs: must NOT change the hash (a server at
        // parallelism 4 happily drives workers launched without it,
        // and a scalar-kernel server drives simd-kernel workers)
        b.parallelism = 8;
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.fp8_kernel = KernelKind::Scalar;
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.agg = AggMode::Tree { nodes: 8 };
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.seed = 2;
        assert_ne!(a.fingerprint(), b.fingerprint());
        let mut c = a.clone();
        c.lr *= 2.0;
        assert_ne!(a.fingerprint(), c.fingerprint());
        let d = ExperimentConfig::preset("lenet_c10:uq:dir03").unwrap();
        assert_ne!(a.fingerprint(), d.fingerprint());
        // the cohort draw IS trajectory: --cohort must be
        // fingerprint-visible
        let mut e = a.clone();
        e.participation += 2;
        assert_ne!(a.fingerprint(), e.fingerprint());
    }

    #[test]
    fn net_cfg_parses_roles() {
        let args = |s: &str| {
            Args::parse(s.split_whitespace().map(String::from))
        };
        assert!(NetCfg::from_args(&args("run --preset x"))
            .unwrap()
            .is_none());
        let n = NetCfg::from_args(&args(
            "run --role server --listen 127.0.0.1:0 --workers 4",
        ))
        .unwrap()
        .unwrap();
        assert_eq!(n.role, NetRole::Server);
        assert_eq!(n.addr, "127.0.0.1:0");
        assert_eq!(n.workers, 4);
        assert_eq!(n.timeout_ms, 30_000);
        // v2 defaults: a 4-deep in-flight window, 1 s heartbeats
        // (derived: min(1000, 30000/4)), hedging off
        assert_eq!(n.inflight, Inflight::Fixed(4));
        assert_eq!(n.heartbeat_ms, 1_000);
        assert_eq!(n.hedge_ms, 0);
        let n = NetCfg::from_args(&args(
            "run --role worker --connect 127.0.0.1:7878 \
             --net-timeout-ms 5000 --net-inflight 8 --heartbeat-ms 0",
        ))
        .unwrap()
        .unwrap();
        assert_eq!(n.role, NetRole::Worker);
        assert_eq!(n.timeout_ms, 5000);
        assert_eq!(n.inflight, Inflight::Fixed(8));
        assert_eq!(n.heartbeat_ms, 0);
        // the window must be positive, and v2 flags without --role
        // are as invalid as the v1 ones
        assert!(NetCfg::from_args(&args(
            "run --role server --listen a:1 --net-inflight 0"
        ))
        .is_err());
        assert!(NetCfg::from_args(&args("run --net-inflight 4")).is_err());
        assert!(NetCfg::from_args(&args("run --heartbeat-ms 9")).is_err());
        // the adaptive window spelling parses on either role
        let n = NetCfg::from_args(&args(
            "run --role server --listen a:1 --net-inflight adaptive",
        ))
        .unwrap()
        .unwrap();
        assert_eq!(n.inflight, Inflight::Adaptive);
        // small timeouts now WORK: the default heartbeat is derived
        // as min(1000, timeout/4), so --net-timeout-ms 800 probes at
        // 200 ms instead of failing the probe-before-deadline guard
        // at startup (the old fixed 1000 ms default)
        let n = NetCfg::from_args(&args(
            "run --role server --listen a:1 --net-timeout-ms 800",
        ))
        .unwrap()
        .unwrap();
        assert_eq!(n.heartbeat_ms, 200);
        // boundary: exactly 1000 derives 250; 4001+ saturates at 1000
        let n = NetCfg::from_args(&args(
            "run --role worker --connect a:1 --net-timeout-ms 1000",
        ))
        .unwrap()
        .unwrap();
        assert_eq!(n.heartbeat_ms, 250);
        let n = NetCfg::from_args(&args(
            "run --role worker --connect a:1 --net-timeout-ms 8000",
        ))
        .unwrap()
        .unwrap();
        assert_eq!(n.heartbeat_ms, 1_000);
        // an EXPLICIT probe interval at or past the idle deadline is
        // still the same startup error it always was
        assert!(NetCfg::from_args(&args(
            "run --role server --listen a:1 --net-timeout-ms 800 \
             --heartbeat-ms 1000"
        ))
        .is_err());
        assert!(NetCfg::from_args(&args(
            "run --role worker --connect a:1 --heartbeat-ms 30000"
        ))
        .is_err()); // == default timeout
        assert!(NetCfg::from_args(&args(
            "run --role server --listen a:1 --net-timeout-ms 800 \
             --heartbeat-ms 0"
        ))
        .is_ok()); // probing off: any deadline is fine
        // hedging: server-only, must undercut the deadline
        let n = NetCfg::from_args(&args(
            "run --role server --listen a:1 --net-hedge-ms 250",
        ))
        .unwrap()
        .unwrap();
        assert_eq!(n.hedge_ms, 250);
        assert!(NetCfg::from_args(&args(
            "run --role server --listen a:1 --net-timeout-ms 800 \
             --net-hedge-ms 800"
        ))
        .is_err());
        assert!(NetCfg::from_args(&args(
            "run --role worker --connect a:1 --net-hedge-ms 100"
        ))
        .is_err());
        assert!(NetCfg::from_args(&args("run --net-hedge-ms 5")).is_err());
        // missing / inconsistent combinations are typed errors
        assert!(NetCfg::from_args(&args("run --role server")).is_err());
        assert!(NetCfg::from_args(&args("run --role worker")).is_err());
        assert!(NetCfg::from_args(&args(
            "run --role worker --connect a:1 --workers 2"
        ))
        .is_err());
        assert!(NetCfg::from_args(&args(
            "run --role server --listen a:1 --connect b:2"
        ))
        .is_err());
        assert!(
            NetCfg::from_args(&args("run --role alien --listen x"))
                .is_err()
        );
        assert!(
            NetCfg::from_args(&args("run --listen 127.0.0.1:1"))
                .is_err()
        );
        // --net-token: carried on either role, orphaned without one,
        // and an empty secret is a config error, not "auth off"
        let n = NetCfg::from_args(&args(
            "run --role server --listen a:1 --net-token hunter2",
        ))
        .unwrap()
        .unwrap();
        assert_eq!(n.token.as_deref(), Some("hunter2"));
        let n = NetCfg::from_args(&args(
            "run --role worker --connect a:1 --net-token hunter2",
        ))
        .unwrap()
        .unwrap();
        assert_eq!(n.token.as_deref(), Some("hunter2"));
        let n = NetCfg::from_args(&args(
            "run --role worker --connect a:1",
        ))
        .unwrap()
        .unwrap();
        assert_eq!(n.token, None);
        assert!(
            NetCfg::from_args(&args("run --net-token x")).is_err()
        );
        assert!(NetCfg::from_args(&args(
            "run --role server --listen a:1 --net-token="
        ))
        .is_err());
    }

    #[test]
    fn aggregator_role_parses_and_guards() {
        let args = |s: &str| {
            Args::parse(s.split_whitespace().map(String::from))
        };
        // full spelling: upstream --connect, downstream --listen,
        // a shard pin, and a downstream worker count
        let n = NetCfg::from_args(&args(
            "run --role aggregator --connect 127.0.0.1:7878 \
             --listen 127.0.0.1:7879 --shard 1/4 --workers 2",
        ))
        .unwrap()
        .unwrap();
        assert_eq!(n.role, NetRole::Aggregator);
        assert_eq!(n.addr, "127.0.0.1:7878");
        assert_eq!(n.listen.as_deref(), Some("127.0.0.1:7879"));
        assert_eq!(n.shard, Some((1, 4)));
        assert_eq!(n.workers, 2);
        // the pin is optional: the root assigns shards in
        // connection order when absent
        let n = NetCfg::from_args(&args(
            "run --role aggregator --connect a:1 --listen b:2",
        ))
        .unwrap()
        .unwrap();
        assert_eq!(n.shard, None);
        assert_eq!(n.workers, 1);
        // both endpoints are required — a mid-tier with only one
        // side is a misconfiguration, not a default
        assert!(NetCfg::from_args(&args(
            "run --role aggregator --connect a:1"
        ))
        .is_err());
        assert!(NetCfg::from_args(&args(
            "run --role aggregator --listen b:2"
        ))
        .is_err());
        // bad shard specs are typed errors with a pinned message
        let typed = |a: &str| {
            NetCfg::from_args(&args(a))
                .unwrap_err()
                .downcast::<ConfigError>()
                .expect("typed ConfigError")
        };
        for bad in ["4/4", "5/4", "x/4", "2/x", "2", "2/0", "-1/4"] {
            let e = typed(&format!(
                "run --role aggregator --connect a:1 --listen b:2 \
                 --shard {bad}"
            ));
            assert_eq!(
                e,
                ConfigError::BadShardSpec {
                    spec: bad.to_string()
                },
                "{bad}"
            );
        }
        assert_eq!(
            typed(
                "run --role aggregator --connect a:1 --listen b:2 \
                 --shard 7"
            )
            .to_string(),
            "bad --shard '7' (expected i/G with 0 <= i < G, e.g. \
             --shard 0/2)"
        );
        // --shard on any other role is its own typed error
        let e = typed("run --role server --listen a:1 --shard 0/2");
        assert_eq!(e, ConfigError::ShardWithoutAggregator);
        assert_eq!(
            e.to_string(),
            "--shard only applies to --role aggregator (the \
             mid-tier role that owns a cohort shard)"
        );
        let e = typed("run --role worker --connect a:1 --shard 0/2");
        assert_eq!(e, ConfigError::ShardWithoutAggregator);
        // ...and without any role it is an orphan like the rest
        assert!(NetCfg::from_args(&args("run --shard 0/2")).is_err());
    }

    #[test]
    fn aimd_flags_parse_and_guard() {
        let args = |s: &str| {
            Args::parse(s.split_whitespace().map(String::from))
        };
        // defaults match the historical hard-coded constants, so
        // existing launches see identical window behavior
        let n = NetCfg::from_args(&args(
            "run --role server --listen a:1",
        ))
        .unwrap()
        .unwrap();
        assert_eq!((n.aimd_spike, n.aimd_cap), (4, 32));
        // explicit values parse on dispatching roles
        let n = NetCfg::from_args(&args(
            "run --role server --listen a:1 --net-aimd-spike 8 \
             --net-aimd-cap 64",
        ))
        .unwrap()
        .unwrap();
        assert_eq!((n.aimd_spike, n.aimd_cap), (8, 64));
        let n = NetCfg::from_args(&args(
            "run --role aggregator --connect a:1 --listen b:2 \
             --net-aimd-spike 2 --net-aimd-cap 1",
        ))
        .unwrap()
        .unwrap();
        assert_eq!((n.aimd_spike, n.aimd_cap), (2, 1));
        // bounds are typed errors with pinned Display strings
        let typed = |a: &str| {
            NetCfg::from_args(&args(a))
                .unwrap_err()
                .downcast::<ConfigError>()
                .expect("typed ConfigError")
        };
        let e =
            typed("run --role server --listen a:1 --net-aimd-spike 1");
        assert_eq!(e, ConfigError::AimdSpikeTooSmall { got: 1 });
        assert_eq!(
            e.to_string(),
            "--net-aimd-spike must be at least 2 (got 1): a spike \
             threshold under 2x would halve the window on ordinary \
             latency jitter"
        );
        let e =
            typed("run --role server --listen a:1 --net-aimd-cap 0");
        assert_eq!(e, ConfigError::AimdCapZero);
        assert_eq!(
            e.to_string(),
            "--net-aimd-cap must be at least 1 (a zero cap would \
             never let a connection carry a job)"
        );
        // workers never own a dispatch window
        assert!(NetCfg::from_args(&args(
            "run --role worker --connect a:1 --net-aimd-spike 8"
        ))
        .is_err());
        assert!(NetCfg::from_args(&args(
            "run --role worker --connect a:1 --net-aimd-cap 16"
        ))
        .is_err());
        // and without a role both flags are orphans
        assert!(
            NetCfg::from_args(&args("run --net-aimd-spike 8")).is_err()
        );
        assert!(
            NetCfg::from_args(&args("run --net-aimd-cap 16")).is_err()
        );
    }

    #[test]
    fn snapshot_flags_parse_and_guard() {
        let args = |s: &str| {
            Args::parse(s.split_whitespace().map(String::from))
        };
        // off by default
        let s = SnapshotCfg::from_args(&args("run"), None).unwrap();
        assert!(!s.enabled() && !s.resume);
        // full spelling
        let s = SnapshotCfg::from_args(
            &args(
                "run --snapshot-dir /tmp/st --snapshot-every 5 \
                 --resume",
            ),
            None,
        )
        .unwrap();
        assert_eq!(s.dir.as_deref(), Some(Path::new("/tmp/st")));
        assert_eq!(s.every, 5);
        assert!(s.resume && s.enabled());
        // cadence defaults to every round boundary
        let s = SnapshotCfg::from_args(
            &args("run --snapshot-dir d"),
            None,
        )
        .unwrap();
        assert_eq!(s.every, 1);

        // typed guards, Display strings pinned: orphan knobs...
        let typed = |a: &str, net: Option<&NetCfg>| {
            SnapshotCfg::from_args(&args(a), net)
                .unwrap_err()
                .downcast::<ConfigError>()
                .expect("typed ConfigError")
        };
        let e = typed("run --resume", None);
        assert_eq!(
            e,
            ConfigError::SnapshotFlagWithoutDir { flag: "resume" }
        );
        assert_eq!(
            e.to_string(),
            "--resume requires --snapshot-dir DIR (no snapshot \
             directory to use)"
        );
        let e = typed("run --snapshot-every 3", None);
        assert_eq!(
            e,
            ConfigError::SnapshotFlagWithoutDir {
                flag: "snapshot-every"
            }
        );
        // ...a zero cadence...
        let e = typed("run --snapshot-dir d --snapshot-every 0", None);
        assert_eq!(e, ConfigError::SnapshotEveryZero);
        assert_eq!(
            e.to_string(),
            "--snapshot-every must be at least 1 (0 would never \
             write a snapshot)"
        );
        // ...and snapshot knobs on a worker launch
        let worker = NetCfg::from_args(&args(
            "run --role worker --connect a:1",
        ))
        .unwrap()
        .unwrap();
        let e = typed("run --snapshot-dir d", Some(&worker));
        assert_eq!(
            e,
            ConfigError::SnapshotOnWorker { flag: "snapshot-dir" }
        );
        assert_eq!(
            e.to_string(),
            "--snapshot-dir only applies to the coordinator; worker \
             and aggregator roles hold no durable round state"
        );
        let e = typed("run --resume", Some(&worker));
        assert_eq!(
            e,
            ConfigError::SnapshotOnWorker { flag: "resume" }
        );
        // a server role takes them fine
        let server = NetCfg::from_args(&args(
            "run --role server --listen a:1",
        ))
        .unwrap()
        .unwrap();
        assert!(SnapshotCfg::from_args(
            &args("run --snapshot-dir d --resume"),
            Some(&server)
        )
        .is_ok());
    }

    #[test]
    fn agg_mode_parses_and_displays() {
        assert_eq!("flat".parse::<AggMode>().unwrap(), AggMode::Flat);
        assert_eq!(
            "tree:16".parse::<AggMode>().unwrap(),
            AggMode::Tree { nodes: 16 }
        );
        assert_eq!(AggMode::Tree { nodes: 16 }.to_string(), "tree:16");
        assert_eq!(AggMode::Flat.to_string(), "flat");
        for bad in ["tree:0", "tree:", "tree", "fanout:2", "TREE:4"] {
            assert_eq!(
                bad.parse::<AggMode>().unwrap_err(),
                ConfigError::BadAggMode { spec: bad.to_string() },
                "{bad}"
            );
        }
    }

    #[test]
    fn validate_scale_knobs_with_typed_errors() {
        let base = ExperimentConfig::preset("lenet_c10:uq:iid").unwrap();
        assert!(base.validate().is_ok());
        let mut c = base.clone();
        c.participation = c.clients + 1;
        assert_eq!(
            c.validate().unwrap_err(),
            ConfigError::CohortExceedsPopulation {
                cohort: 41,
                clients: 40
            }
        );
        c.participation = 0;
        assert_eq!(c.validate().unwrap_err(), ConfigError::CohortZero);
        c.participation = 4;
        c.clients = 0;
        assert_eq!(c.validate().unwrap_err(), ConfigError::NoClients);
        let mut t = base.clone();
        t.agg = AggMode::Tree { nodes: 0 };
        assert!(matches!(
            t.validate().unwrap_err(),
            ConfigError::BadAggMode { .. }
        ));
        t.agg = AggMode::Tree { nodes: 4 };
        assert!(t.validate().is_ok());
        t.server_opt = Some(ServerOptCfg::default());
        assert_eq!(
            t.validate().unwrap_err(),
            ConfigError::TreeWithServerOpt
        );
    }

    #[test]
    fn scale_flags_parse_and_guard() {
        let args = |s: &str| {
            Args::parse(s.split_whitespace().map(String::from))
        };
        let base =
            || ExperimentConfig::preset("lenet_c10:uq:iid").unwrap();
        // --cohort is P in the paper's P-of-K notation
        let mut c = base();
        c.apply_scale_flags(&args("run --cohort 25")).unwrap();
        assert_eq!(c.participation, 25);
        // --cohort-frac scales off K (40 clients here)
        let mut c = base();
        c.apply_scale_flags(&args("run --cohort-frac 0.25")).unwrap();
        assert_eq!(c.participation, 10);
        // --agg rides along
        let mut c = base();
        c.apply_scale_flags(&args("run --cohort 8 --agg tree:4"))
            .unwrap();
        assert_eq!(
            (c.participation, c.agg),
            (8, AggMode::Tree { nodes: 4 })
        );
        // no scale flags: a no-op on a valid config
        let mut c = base();
        c.apply_scale_flags(&args("run")).unwrap();
        assert_eq!(c.participation, base().participation);
        // conflicts and bounds are typed errors (NetCfg guard style)
        for bad in [
            "run --cohort 8 --cohort-frac 0.5",
            "run --cohort 8 --participation 8",
            "run --cohort-frac 0.5 --participation 8",
            "run --cohort 0",
            "run --cohort 41",
            "run --cohort-frac 0.0",
            "run --cohort-frac 1.5",
            "run --cohort-frac nan",
            "run --agg tree:0",
            "run --agg diamond",
            "run --cohort nope",
        ] {
            assert!(
                base().apply_scale_flags(&args(bad)).is_err(),
                "expected rejection: {bad}"
            );
        }
    }

    #[test]
    fn table2_arms_differ_only_in_quantizers() {
        let a = ExperimentConfig::preset("lenet_c100:nocq_det:iid").unwrap();
        let b = ExperimentConfig::preset("lenet_c100:nocq_rand:iid").unwrap();
        assert_eq!(a.comm, Rounding::None);
        assert_eq!(b.comm, Rounding::None);
        assert_eq!(a.qat, QatMode::Det);
        assert_eq!(b.qat, QatMode::Rand);
        assert_eq!(a.rounds, b.rounds);
    }

    #[test]
    fn daemon_flags_parse_and_guard() {
        let args = |s: &str| {
            Args::parse(s.split_whitespace().map(String::from))
        };
        // off by default, and on server/worker launches
        assert!(DaemonCfg::from_args(&args("run --preset x"))
            .unwrap()
            .is_none());
        assert!(DaemonCfg::from_args(&args(
            "run --role server --listen a:1"
        ))
        .unwrap()
        .is_none());
        // full spelling
        let d = DaemonCfg::from_args(&args(
            "run --role daemon --queue-dir /tmp/q --daemon-slots 3",
        ))
        .unwrap()
        .unwrap();
        assert_eq!(d.queue_dir, Path::new("/tmp/q"));
        assert_eq!(d.slots, 3);
        // slots default to strictly sequential
        let d = DaemonCfg::from_args(&args(
            "run --role daemon --queue-dir q",
        ))
        .unwrap()
        .unwrap();
        assert_eq!(d.slots, 1);

        // typed guards, Display strings pinned: orphan knobs...
        let typed = |a: &str| {
            DaemonCfg::from_args(&args(a))
                .unwrap_err()
                .downcast::<ConfigError>()
                .expect("typed ConfigError")
        };
        let e = typed("run --queue-dir q");
        assert_eq!(
            e,
            ConfigError::DaemonFlagWithoutRole { flag: "queue-dir" }
        );
        assert_eq!(
            e.to_string(),
            "--queue-dir only makes sense with --role daemon"
        );
        let e = typed("run --role server --listen a:1 --daemon-slots 2");
        assert_eq!(
            e,
            ConfigError::DaemonFlagWithoutRole {
                flag: "daemon-slots"
            }
        );
        // ...a missing queue...
        let e = typed("run --role daemon");
        assert_eq!(e, ConfigError::DaemonWithoutQueueDir);
        assert_eq!(
            e.to_string(),
            "--role daemon requires --queue-dir DIR (no job queue \
             to schedule)"
        );
        // ...a zero slot count...
        let e = typed("run --role daemon --queue-dir q --daemon-slots 0");
        assert_eq!(e, ConfigError::DaemonSlotsZero);
        assert_eq!(
            e.to_string(),
            "--daemon-slots must be at least 1 (0 would never start \
             a job)"
        );
        // ...and net flags leaking onto a daemon launch
        assert!(DaemonCfg::from_args(&args(
            "run --role daemon --queue-dir q --listen a:1"
        ))
        .is_err());
        assert!(DaemonCfg::from_args(&args(
            "run --role daemon --queue-dir q --net-hedge-ms 50"
        ))
        .is_err());
    }

    #[test]
    fn telemetry_flag_parses_and_guards() {
        let args = |s: &str| {
            Args::parse(s.split_whitespace().map(String::from))
        };
        assert!(telemetry_listen_from_args(&args("run"), None)
            .unwrap()
            .is_none());
        let t = telemetry_listen_from_args(
            &args("run --telemetry-listen 127.0.0.1:7979"),
            None,
        )
        .unwrap();
        assert_eq!(t.as_deref(), Some("127.0.0.1:7979"));
        // fine on the coordinator role...
        let server = NetCfg::from_args(&args(
            "run --role server --listen a:1",
        ))
        .unwrap()
        .unwrap();
        assert!(telemetry_listen_from_args(
            &args("run --telemetry-listen b:2"),
            Some(&server)
        )
        .is_ok());
        // ...typed error on a worker, Display pinned
        let worker = NetCfg::from_args(&args(
            "run --role worker --connect a:1",
        ))
        .unwrap()
        .unwrap();
        let e = telemetry_listen_from_args(
            &args("run --telemetry-listen b:2"),
            Some(&worker),
        )
        .unwrap_err()
        .downcast::<ConfigError>()
        .expect("typed ConfigError");
        assert_eq!(e, ConfigError::TelemetryOnWorker);
        assert_eq!(
            e.to_string(),
            "--telemetry-listen only applies to processes that \
             drive the round loop; worker and aggregator roles \
             never emit telemetry"
        );
        // an empty address is a config error, not "telemetry off"
        assert!(telemetry_listen_from_args(
            &args("run --telemetry-listen="),
            None
        )
        .is_err());
    }

    #[test]
    fn config_json_roundtrip_is_lossless() {
        // exercise every non-default encoding arm at once
        let mut c = ExperimentConfig::preset("kwt:uq+:speaker").unwrap();
        c.split = SplitCfg::Dirichlet(0.3);
        c.seed = 0xDEAD_BEEF;
        c.lr = 0.007; // not exactly representable: bit-exactness test
        c.fp32_client_frac = 0.125;
        c.error_feedback = true;
        c.fp8_kernel = KernelKind::Scalar;
        c.participation = 4; // tree + server_opt is invalid; keep flat
        let text = c.to_json().to_string();
        let back =
            ExperimentConfig::from_json(&Json::parse(&text).unwrap())
                .unwrap();
        // Debug covers every field; the fingerprint re-checks the
        // trajectory ones through the bit-pattern lens
        assert_eq!(format!("{c:?}"), format!("{back:?}"));
        assert_eq!(c.fingerprint(), back.fingerprint());

        // a big seed travels as a decimal string, losslessly
        c.seed = u64::MAX - 7;
        let text = c.to_json().to_string();
        assert!(text.contains(&format!("\"{}\"", u64::MAX - 7)));
        let back =
            ExperimentConfig::from_json(&Json::parse(&text).unwrap())
                .unwrap();
        assert_eq!(back.seed, u64::MAX - 7);
    }

    #[test]
    fn config_from_json_accepts_sparse_specs_and_rejects_bad_ones() {
        // three-line hand-written spec: base + method + one override
        let v = Json::parse(
            r#"{"model": "lenet_c10", "method": "bq_ef",
                "rounds": 7}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&v).unwrap();
        assert_eq!(c.name, "lenet_c10_bq_ef");
        assert_eq!(c.rounds, 7);
        assert_eq!(c.comm, Rounding::Deterministic);
        assert!(c.error_feedback);
        // model-only spec gets the model as its job label
        let c = ExperimentConfig::from_json(
            &Json::parse(r#"{"model": "mlp_c10"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(c.name, "mlp_c10");
        // missing model, unknown model, and invalid scale knobs all
        // fail (the last one through validate(), typed)
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"rounds": 3}"#).unwrap()
        )
        .is_err());
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"model": "nope"}"#).unwrap()
        )
        .is_err());
        let e = ExperimentConfig::from_json(
            &Json::parse(
                r#"{"model": "mlp_c10", "participation": 99}"#,
            )
            .unwrap(),
        )
        .unwrap_err()
        .downcast::<ConfigError>()
        .expect("typed ConfigError");
        assert_eq!(
            e,
            ConfigError::CohortExceedsPopulation {
                cohort: 99,
                clients: 40
            }
        );
    }
}
