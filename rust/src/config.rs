//! Experiment configuration + named presets for every paper table row.
//!
//! A config fully determines a run: model variant, data generator,
//! client split, FL hyperparameters, quantizer switches and the
//! ServerOptimize settings. The Table-2 ablation grid and the Figure-2
//! method family are all *config switches* on the same coordinator —
//! no code forks (DESIGN.md §7).

use anyhow::{bail, Result};

use crate::fp8::Rounding;

#[derive(Clone, Debug, PartialEq)]
pub enum SplitCfg {
    Iid,
    /// Dirichlet label skew with the given concentration (paper: 0.3).
    Dirichlet(f64),
    /// One client per synthetic speaker (speech tasks).
    Speaker,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QatMode {
    /// Deterministic FP8 QAT (the paper's training default).
    Det,
    /// Stochastic FP8 QAT (Table 2 ablation arm).
    Rand,
    /// No quantization: FP32 baseline.
    None,
}

impl QatMode {
    pub fn artifact_suffix(&self) -> &'static str {
        match self {
            QatMode::Det => "det",
            QatMode::Rand => "rand",
            QatMode::None => "none",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LrSchedule {
    Const,
    /// Cosine decay over rounds to `final_frac * lr` (speech setup).
    Cosine { final_frac: f32 },
}

impl LrSchedule {
    pub fn lr_at(&self, base: f32, round: usize, total: usize) -> f32 {
        match self {
            LrSchedule::Const => base,
            LrSchedule::Cosine { final_frac } => {
                let t = round as f32 / total.max(1) as f32;
                let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
                base * (final_frac + (1.0 - final_frac) * cos)
            }
        }
    }
}

/// ServerOptimize (UQ+) settings — Eq. (4) GD steps + Eq. (5) grid.
#[derive(Clone, Debug, PartialEq)]
pub struct ServerOptCfg {
    pub gd_steps: usize,
    pub gd_lr: f32,
    pub grid_points: usize,
}

impl Default for ServerOptCfg {
    fn default() -> Self {
        // paper §4: 5 GD steps, lr grid-searched in {0.01,0.1,1},
        // 50 grid points for alpha
        Self {
            gd_steps: 5,
            gd_lr: 0.1,
            grid_points: 50,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    /// Manifest model-variant name (e.g. "lenet_c10").
    pub model: String,
    pub split: SplitCfg,
    /// K — total client count.
    pub clients: usize,
    /// P — participating clients per round (must equal the artifact's
    /// baked `server_p` when ServerOptimize is enabled).
    pub participation: usize,
    pub rounds: usize,
    pub lr: f32,
    pub weight_decay: f32,
    pub schedule: LrSchedule,
    /// QAT quantizer during local training.
    pub qat: QatMode,
    /// Communication quantizer (uplink + downlink).
    pub comm: Rounding,
    pub server_opt: Option<ServerOptCfg>,
    pub eval_every: usize,
    pub seed: u64,
    pub n_train: usize,
    pub n_test: usize,
    /// Synthetic speakers (speech tasks).
    pub speakers: usize,
    pub flip_aug: bool,
    /// Extension (paper Remark 3): error-feedback memory on both
    /// links, making *biased* communication viable (EF à la
    /// Richtárik et al.; the paper cites EF21 as the fix for BQ).
    pub error_feedback: bool,
    /// Extension (paper §5 future work): fraction of clients training
    /// in full FP32 (heterogeneous hardware fleets); all clients still
    /// communicate through the configured wire quantizer.
    pub fp32_client_frac: f32,
    /// Worker threads for the per-round client fan-out (the cohort is
    /// embarrassingly parallel). Results are bit-identical for every
    /// value — per-client RNG streams are counter-derived and
    /// aggregation applies uplinks in cohort order — so this is purely
    /// a wall-clock knob. 1 = sequential (no threads spawned).
    pub parallelism: usize,
}

impl ExperimentConfig {
    /// Base config per model variant (scaled-down counterpart of the
    /// paper's §4 setup; see DESIGN.md §Substitutions for the mapping).
    pub fn base(model: &str) -> Result<ExperimentConfig> {
        let vision = ExperimentConfig {
            name: String::new(),
            model: model.to_string(),
            split: SplitCfg::Iid,
            clients: 40,
            participation: 10,
            rounds: 60,
            lr: 0.1,
            weight_decay: 1e-3,
            schedule: LrSchedule::Const,
            qat: QatMode::Det,
            comm: Rounding::Stochastic,
            server_opt: None,
            eval_every: 2,
            seed: 1,
            n_train: 4000,
            n_test: 1024,
            speakers: 0,
            flip_aug: true,
            error_feedback: false,
            fp32_client_frac: 0.0,
            parallelism: 1,
        };
        Ok(match model {
            "mlp_c10" | "lenet_c10" | "lenet_c100" | "resnet8_c10"
            | "resnet8_c100" => vision,
            "matchbox" | "kwt" => ExperimentConfig {
                clients: 64,
                participation: 8,
                rounds: 50,
                lr: 1e-3,
                weight_decay: 0.1,
                schedule: LrSchedule::Cosine { final_frac: 0.05 },
                split: SplitCfg::Speaker,
                n_train: 3200,
                n_test: 768,
                speakers: 64,
                flip_aug: false,
                ..vision
            },
            _ => bail!("unknown model variant '{model}'"),
        })
    }

    /// Apply a named method arm (the Figure-2 family / Table columns).
    pub fn with_method(mut self, method: &str) -> Result<ExperimentConfig> {
        match method {
            // FP32 FedAvg baseline
            "fp32" => {
                self.qat = QatMode::None;
                self.comm = Rounding::None;
                self.server_opt = None;
            }
            // FP8FedAvg-UQ (paper's main method)
            "uq" => {
                self.qat = QatMode::Det;
                self.comm = Rounding::Stochastic;
                self.server_opt = None;
            }
            // FP8FedAvg-UQ+ (with ServerOptimize)
            "uq+" => {
                self.qat = QatMode::Det;
                self.comm = Rounding::Stochastic;
                self.server_opt = Some(ServerOptCfg::default());
            }
            // biased communication ablation (Fig. 2 "BQ", Table 2 det CQ)
            "bq" => {
                self.qat = QatMode::Det;
                self.comm = Rounding::Deterministic;
                self.server_opt = None;
            }
            // Table 2: stochastic QAT with (rand) CQ
            "randqat" => {
                self.qat = QatMode::Rand;
                self.comm = Rounding::Stochastic;
                self.server_opt = None;
            }
            // Table 2: FP8 QAT without communication quantization
            "nocq_det" => {
                self.qat = QatMode::Det;
                self.comm = Rounding::None;
                self.server_opt = None;
            }
            "nocq_rand" => {
                self.qat = QatMode::Rand;
                self.comm = Rounding::None;
                self.server_opt = None;
            }
            // extension: biased CQ rescued by error feedback
            "bq_ef" => {
                self.qat = QatMode::Det;
                self.comm = Rounding::Deterministic;
                self.server_opt = None;
                self.error_feedback = true;
            }
            // extension: half the fleet trains in FP32 (heterogeneous
            // hardware), everyone communicates in FP8-UQ
            "mixed" => {
                self.qat = QatMode::Det;
                self.comm = Rounding::Stochastic;
                self.server_opt = None;
                self.fp32_client_frac = 0.5;
            }
            _ => bail!(
                "unknown method '{method}' (fp32|uq|uq+|bq|randqat|\
                 nocq_det|nocq_rand|bq_ef|mixed)"
            ),
        }
        self.name = format!("{}_{}", self.model, method);
        Ok(self)
    }

    pub fn with_split(mut self, split: &str) -> Result<ExperimentConfig> {
        self.split = match split {
            "iid" => SplitCfg::Iid,
            "dir03" => SplitCfg::Dirichlet(0.3),
            "speaker" => SplitCfg::Speaker,
            _ => bail!("unknown split '{split}' (iid|dir03|speaker)"),
        };
        if !self.name.is_empty() {
            self.name = format!("{}_{}", self.name, split);
        }
        Ok(self)
    }

    /// Parse "model:method:split" preset notation.
    pub fn preset(spec: &str) -> Result<ExperimentConfig> {
        let parts: Vec<&str> = spec.split(':').collect();
        match parts.as_slice() {
            [model, method, split] => Self::base(model)?
                .with_method(method)?
                .with_split(split),
            [model, method] => Self::base(model)?.with_method(method),
            _ => bail!("preset must be model:method[:split], got '{spec}'"),
        }
    }

    /// Uplink+downlink payload cost is FP32 iff comm == None.
    pub fn is_fp32_comm(&self) -> bool {
        self.comm == Rounding::None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_roundtrip() {
        let c = ExperimentConfig::preset("lenet_c10:uq+:dir03").unwrap();
        assert_eq!(c.model, "lenet_c10");
        assert_eq!(c.qat, QatMode::Det);
        assert_eq!(c.comm, Rounding::Stochastic);
        assert!(c.server_opt.is_some());
        assert_eq!(c.split, SplitCfg::Dirichlet(0.3));
        assert_eq!(c.name, "lenet_c10_uq+_dir03");
    }

    #[test]
    fn fp32_preset_has_no_quant() {
        let c = ExperimentConfig::preset("resnet8_c10:fp32:iid").unwrap();
        assert_eq!(c.qat, QatMode::None);
        assert_eq!(c.comm, Rounding::None);
        assert!(c.is_fp32_comm());
    }

    #[test]
    fn speech_defaults() {
        let c = ExperimentConfig::preset("kwt:uq:speaker").unwrap();
        assert_eq!(c.split, SplitCfg::Speaker);
        assert!(matches!(c.schedule, LrSchedule::Cosine { .. }));
        assert_eq!(c.participation, 8);
    }

    #[test]
    fn parallelism_defaults_to_sequential() {
        let c = ExperimentConfig::preset("lenet_c10:uq:iid").unwrap();
        assert_eq!(c.parallelism, 1);
    }

    #[test]
    fn rejects_unknown() {
        assert!(ExperimentConfig::preset("nope:uq:iid").is_err());
        assert!(ExperimentConfig::preset("lenet_c10:nope:iid").is_err());
        assert!(ExperimentConfig::preset("lenet_c10:uq:nope").is_err());
    }

    #[test]
    fn cosine_schedule_decays() {
        let s = LrSchedule::Cosine { final_frac: 0.1 };
        let l0 = s.lr_at(1.0, 0, 100);
        let l50 = s.lr_at(1.0, 50, 100);
        let l100 = s.lr_at(1.0, 100, 100);
        assert!((l0 - 1.0).abs() < 1e-6);
        assert!(l50 < l0 && l100 < l50);
        assert!((l100 - 0.1).abs() < 1e-6);
    }

    #[test]
    fn table2_arms_differ_only_in_quantizers() {
        let a = ExperimentConfig::preset("lenet_c100:nocq_det:iid").unwrap();
        let b = ExperimentConfig::preset("lenet_c100:nocq_rand:iid").unwrap();
        assert_eq!(a.comm, Rounding::None);
        assert_eq!(b.comm, Rounding::None);
        assert_eq!(a.qat, QatMode::Det);
        assert_eq!(b.qat, QatMode::Rand);
        assert_eq!(a.rounds, b.rounds);
    }
}
