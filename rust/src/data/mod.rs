//! Synthetic data substrate + federated partitioners.
//!
//! The paper evaluates on CIFAR10/100 and Google SpeechCommands v2.
//! Those are not available offline, so we build class-conditional
//! generators that exercise the identical training / quantization /
//! aggregation code paths (DESIGN.md §Substitutions): what matters for
//! reproducing the paper's *comparisons* is the relative behaviour of
//! FP32 vs FP8-UQ/UQ+ on the same learnable task, not absolute
//! accuracy on natural images/audio.

pub mod partition;
pub mod speech;
pub mod vision;

use crate::fp8::rng::Pcg32;

/// An in-memory labelled dataset with flattened features.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Row-major [n, feat_len] features.
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    /// Per-example feature shape (e.g. [8,8,3] or [32,16]).
    pub feat_shape: Vec<usize>,
    pub classes: usize,
    /// Optional per-example group id (speaker) for speaker partitioning.
    pub group: Vec<u32>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn feat_len(&self) -> usize {
        self.feat_shape.iter().product()
    }

    pub fn example(&self, i: usize) -> &[f32] {
        let f = self.feat_len();
        &self.x[i * f..(i + 1) * f]
    }
}

/// Assemble `u` training batches of size `b` by sampling (with
/// replacement) from a client's shard; optional horizontal-flip
/// augmentation for vision data (paper: random crop + flip; we keep
/// the flip, the cheap half, in the coordinator's data path).
pub fn make_batches(
    ds: &Dataset,
    shard: &[usize],
    u: usize,
    b: usize,
    rng: &mut Pcg32,
    flip_aug: bool,
) -> (Vec<f32>, Vec<i32>) {
    let f = ds.feat_len();
    let mut xs = Vec::with_capacity(u * b * f);
    let mut ys = Vec::with_capacity(u * b);
    let (h, w, c) = match ds.feat_shape.as_slice() {
        [h, w, c] => (*h, *w, *c),
        _ => (0, 0, 0),
    };
    for _ in 0..u * b {
        let idx = shard[rng.below(shard.len())];
        let ex = ds.example(idx);
        if flip_aug && c > 0 && rng.next_u32() & 1 == 1 {
            // horizontal flip on HWC layout
            for hh in 0..h {
                for ww in (0..w).rev() {
                    let base = (hh * w + ww) * c;
                    xs.extend_from_slice(&ex[base..base + c]);
                }
            }
        } else {
            xs.extend_from_slice(ex);
        }
        ys.push(ds.y[idx]);
    }
    (xs, ys)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            x: (0..2 * 2 * 2 * 3).map(|v| v as f32).collect(),
            y: vec![0, 1],
            feat_shape: vec![2, 2, 3],
            classes: 2,
            group: vec![0, 0],
        }
    }

    #[test]
    fn batch_shapes() {
        let ds = tiny();
        let mut rng = Pcg32::new(1, 0);
        let (xs, ys) = make_batches(&ds, &[0, 1], 3, 4, &mut rng, false);
        assert_eq!(xs.len(), 3 * 4 * 12);
        assert_eq!(ys.len(), 12);
    }

    #[test]
    fn flip_reverses_columns() {
        let ds = tiny();
        let mut rng = Pcg32::new(1, 0);
        // force flips by checking both variants appear over many draws
        let (xs, _) = make_batches(&ds, &[0], 64, 1, &mut rng, true);
        let orig = ds.example(0);
        let mut flipped = vec![0.0; 12];
        for hh in 0..2 {
            for ww in 0..2 {
                for cc in 0..3 {
                    flipped[(hh * 2 + ww) * 3 + cc] =
                        orig[(hh * 2 + (1 - ww)) * 3 + cc];
                }
            }
        }
        let mut saw_orig = false;
        let mut saw_flip = false;
        for i in 0..64 {
            let row = &xs[i * 12..(i + 1) * 12];
            if row == orig {
                saw_orig = true;
            }
            if row == flipped.as_slice() {
                saw_flip = true;
            }
        }
        assert!(saw_orig && saw_flip);
    }

    #[test]
    fn batches_only_use_shard() {
        let ds = tiny();
        let mut rng = Pcg32::new(2, 0);
        let (_, ys) = make_batches(&ds, &[1], 2, 8, &mut rng, false);
        assert!(ys.iter().all(|&y| y == 1));
    }
}
