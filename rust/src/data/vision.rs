//! SyntheticVision — CIFAR10/100 stand-in (DESIGN.md §Substitutions).
//!
//! Class-conditional generator: each class has a smooth random
//! prototype image; samples are prototype + i.i.d. Gaussian pixel
//! noise + a global brightness jitter, standardized to ~N(0,1) pixels.
//! With C=100 the prototypes crowd the 192-dim feature space, so the
//! task gets genuinely harder (mirroring CIFAR100 vs CIFAR10), which
//! is what drives the paper's per-dataset differences.

use super::Dataset;
use crate::fp8::rng::Pcg32;

pub struct VisionCfg {
    pub classes: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub noise: f32,
    pub label_noise: f32,
}

impl VisionCfg {
    pub fn new(classes: usize) -> Self {
        Self {
            classes,
            h: 8,
            w: 8,
            c: 3,
            noise: 1.3,
            label_noise: 0.04,
        }
    }
}

fn prototypes(cfg: &VisionCfg, rng: &mut Pcg32) -> Vec<f32> {
    let f = cfg.h * cfg.w * cfg.c;
    let mut protos = vec![0.0f32; cfg.classes * f];
    let mut cache = None;
    for cl in 0..cfg.classes {
        // raw noise, then 3x3 spatial box-blur per channel for smooth,
        // image-like structure
        let raw: Vec<f32> =
            (0..f).map(|_| rng.normal(&mut cache)).collect();
        let dst = &mut protos[cl * f..(cl + 1) * f];
        for hh in 0..cfg.h {
            for ww in 0..cfg.w {
                for cc in 0..cfg.c {
                    let mut acc = 0.0f32;
                    let mut n = 0.0f32;
                    for dh in -1i64..=1 {
                        for dw in -1i64..=1 {
                            let nh = hh as i64 + dh;
                            let nw = ww as i64 + dw;
                            if nh >= 0
                                && nh < cfg.h as i64
                                && nw >= 0
                                && nw < cfg.w as i64
                            {
                                acc += raw[((nh as usize * cfg.w
                                    + nw as usize)
                                    * cfg.c)
                                    + cc];
                                n += 1.0;
                            }
                        }
                    }
                    dst[(hh * cfg.w + ww) * cfg.c + cc] =
                        acc / n * 2.2; // re-amplify post-blur
                }
            }
        }
    }
    protos
}

/// Generate train + test splits from one seed.
pub fn generate(
    cfg: &VisionCfg,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> (Dataset, Dataset) {
    let mut rng = Pcg32::new(seed, 0x5649_5349_4f4e); // "VISION" stream
    let protos = prototypes(cfg, &mut rng);
    let make = |n: usize, rng: &mut Pcg32| -> Dataset {
        let f = cfg.h * cfg.w * cfg.c;
        let mut x = Vec::with_capacity(n * f);
        let mut y = Vec::with_capacity(n);
        let mut cache = None;
        for _ in 0..n {
            let mut cl = rng.below(cfg.classes);
            let bright = 1.0 + 0.1 * rng.normal(&mut cache);
            let proto = &protos[cl * f..(cl + 1) * f];
            for &p in proto {
                x.push(p * bright + cfg.noise * rng.normal(&mut cache));
            }
            if rng.uniform() < cfg.label_noise {
                cl = rng.below(cfg.classes);
            }
            y.push(cl as i32);
        }
        Dataset {
            x,
            y,
            feat_shape: vec![cfg.h, cfg.w, cfg.c],
            classes: cfg.classes,
            group: vec![0; n],
        }
    };
    let train = make(n_train, &mut rng);
    let test = make(n_test, &mut rng);
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let cfg = VisionCfg::new(10);
        let (tr, te) = generate(&cfg, 100, 40, 1);
        assert_eq!(tr.len(), 100);
        assert_eq!(te.len(), 40);
        assert_eq!(tr.feat_len(), 192);
        assert!(tr.y.iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn deterministic() {
        let cfg = VisionCfg::new(10);
        let (a, _) = generate(&cfg, 50, 10, 42);
        let (b, _) = generate(&cfg, 50, 10, 42);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn seeds_differ() {
        let cfg = VisionCfg::new(10);
        let (a, _) = generate(&cfg, 50, 10, 1);
        let (b, _) = generate(&cfg, 50, 10, 2);
        assert_ne!(a.x, b.x);
    }

    #[test]
    fn class_structure_is_learnable() {
        // nearest-prototype classifier must beat chance comfortably
        let cfg = VisionCfg::new(10);
        let (tr, te) = generate(&cfg, 500, 200, 3);
        let f = tr.feat_len();
        // class means from train
        let mut means = vec![0.0f64; 10 * f];
        let mut counts = vec![0.0f64; 10];
        for i in 0..tr.len() {
            let cl = tr.y[i] as usize;
            counts[cl] += 1.0;
            for (j, &v) in tr.example(i).iter().enumerate() {
                means[cl * f + j] += v as f64;
            }
        }
        for cl in 0..10 {
            for j in 0..f {
                means[cl * f + j] /= counts[cl].max(1.0);
            }
        }
        let mut correct = 0;
        for i in 0..te.len() {
            let ex = te.example(i);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f64 = ex
                        .iter()
                        .enumerate()
                        .map(|(j, &v)| {
                            (v as f64 - means[a * f + j]).powi(2)
                        })
                        .sum();
                    let db: f64 = ex
                        .iter()
                        .enumerate()
                        .map(|(j, &v)| {
                            (v as f64 - means[b * f + j]).powi(2)
                        })
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best as i32 == te.y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / te.len() as f64;
        assert!(acc > 0.5, "nearest-prototype acc {acc}");
    }
}
