//! Federated partitioners: i.i.d., Dirichlet(beta) label-skew, and
//! speaker-id grouping — the three client-split regimes of the paper's
//! evaluation (§4: i.i.d., Dir(0.3), speaker-id).

use super::Dataset;
use crate::fp8::rng::Pcg32;

/// The shuffled sample order behind [`iid`]. Virtualized client state
/// (`coordinator::cohort::ClientShards`) stores only this O(n)
/// permutation and materializes any single client's shard on demand;
/// exposing it separately keeps the RNG consumption — one full
/// Fisher-Yates shuffle — identical between the dense and virtual
/// paths.
pub fn iid_order(n: usize, rng: &mut Pcg32) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    for i in (1..n).rev() {
        let j = rng.below(i + 1);
        idx.swap(i, j);
    }
    idx
}

/// Shuffle and split into `k` near-equal shards.
pub fn iid(n: usize, k: usize, rng: &mut Pcg32) -> Vec<Vec<usize>> {
    let idx = iid_order(n, rng);
    let mut shards = vec![Vec::with_capacity(n / k + 1); k];
    for (i, v) in idx.into_iter().enumerate() {
        shards[i % k].push(v);
    }
    shards
}

/// Label-skewed split: for each class, distribute its examples across
/// clients with Dirichlet(concentration) proportions (the standard
/// construction behind the paper's "Dir(0.3)" rows).
pub fn dirichlet(
    ds: &Dataset,
    k: usize,
    concentration: f64,
    rng: &mut Pcg32,
) -> Vec<Vec<usize>> {
    let mut shards = vec![Vec::new(); k];
    for class in 0..ds.classes {
        let members: Vec<usize> = (0..ds.len())
            .filter(|&i| ds.y[i] as usize == class)
            .collect();
        let props = rng.dirichlet(concentration, k);
        // cumulative boundaries over the shuffled member list
        let mut order = members;
        for i in (1..order.len()).rev() {
            let j = rng.below(i + 1);
            order.swap(i, j);
        }
        let n = order.len() as f64;
        let mut start = 0usize;
        let mut acc = 0.0f64;
        for (cl, &p) in props.iter().enumerate() {
            acc += p;
            let end = if cl + 1 == k {
                order.len()
            } else {
                (acc * n).round() as usize
            }
            .min(order.len());
            shards[cl].extend_from_slice(&order[start..end.max(start)]);
            start = end.max(start);
        }
    }
    // guarantee no empty shard (move one example from the largest)
    for i in 0..k {
        if shards[i].is_empty() {
            let largest = (0..k)
                .max_by_key(|&j| shards[j].len())
                .unwrap();
            if let Some(v) = shards[largest].pop() {
                shards[i].push(v);
            }
        }
    }
    shards
}

/// One client per distinct group (speaker) id.
pub fn by_group(ds: &Dataset) -> Vec<Vec<usize>> {
    let k = ds.group.iter().copied().max().map(|m| m as usize + 1)
        .unwrap_or(0);
    let mut shards = vec![Vec::new(); k];
    for (i, &g) in ds.group.iter().enumerate() {
        shards[g as usize].push(i);
    }
    shards.retain(|s| !s.is_empty());
    shards
}

/// Summary statistic used in tests / logs: mean per-client fraction of
/// the majority label (1/classes for perfectly uniform shards).
pub fn skew(ds: &Dataset, shards: &[Vec<usize>]) -> f64 {
    let mut total = 0.0;
    for shard in shards {
        let mut counts = vec![0usize; ds.classes];
        for &i in shard {
            counts[ds.y[i] as usize] += 1;
        }
        let max = *counts.iter().max().unwrap_or(&0);
        total += max as f64 / shard.len().max(1) as f64;
    }
    total / shards.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vision::{generate, VisionCfg};

    fn ds() -> Dataset {
        generate(&VisionCfg::new(10), 1000, 10, 1).0
    }

    #[test]
    fn iid_covers_everything_once() {
        let mut rng = Pcg32::new(1, 0);
        let shards = iid(100, 7, &mut rng);
        let mut all: Vec<usize> =
            shards.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
        assert!(shards.iter().all(|s| s.len() >= 100 / 7));
    }

    #[test]
    fn dirichlet_covers_everything_once() {
        let d = ds();
        let mut rng = Pcg32::new(2, 0);
        let shards = dirichlet(&d, 20, 0.3, &mut rng);
        let mut all: Vec<usize> =
            shards.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all.len(), d.len());
        all.dedup();
        assert_eq!(all.len(), d.len());
        assert!(shards.iter().all(|s| !s.is_empty()));
    }

    #[test]
    fn dirichlet_more_skewed_than_iid() {
        let d = ds();
        let mut rng = Pcg32::new(3, 0);
        let iid_shards = iid(d.len(), 20, &mut rng);
        let dir_shards = dirichlet(&d, 20, 0.3, &mut rng);
        let s_iid = skew(&d, &iid_shards);
        let s_dir = skew(&d, &dir_shards);
        assert!(
            s_dir > s_iid + 0.1,
            "dir skew {s_dir} vs iid skew {s_iid}"
        );
    }

    #[test]
    fn concentration_controls_skew() {
        let d = ds();
        let mut rng = Pcg32::new(4, 0);
        let tight = dirichlet(&d, 20, 100.0, &mut rng);
        let loose = dirichlet(&d, 20, 0.1, &mut rng);
        assert!(skew(&d, &loose) > skew(&d, &tight) + 0.15);
    }

    #[test]
    fn group_partition() {
        let mut d = ds();
        d.group = (0..d.len()).map(|i| (i % 13) as u32).collect();
        let shards = by_group(&d);
        assert_eq!(shards.len(), 13);
        for (g, shard) in shards.iter().enumerate() {
            assert!(shard.iter().all(|&i| d.group[i] as usize == g));
        }
    }
}
