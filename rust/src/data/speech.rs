//! SyntheticSpeech — SpeechCommands stand-in (DESIGN.md §Substitutions).
//!
//! Each class is a frequency-modulated "formant" trajectory over a
//! (T, F) MFCC-like grid; each synthetic *speaker* adds a fixed timbre
//! offset, pitch shift and gain. Utterances carry their speaker id so
//! the speaker-id partitioner reproduces the paper's realistic
//! heterogeneity (each client = one speaker whose class mix and voice
//! are idiosyncratic).

use super::Dataset;
use crate::fp8::rng::Pcg32;

pub struct SpeechCfg {
    pub classes: usize,
    pub t: usize,
    pub f: usize,
    pub speakers: usize,
    pub noise: f32,
}

impl SpeechCfg {
    pub fn new(classes: usize, speakers: usize) -> Self {
        Self {
            classes,
            t: 32,
            f: 16,
            speakers,
            noise: 0.8,
        }
    }
}

struct ClassProto {
    f0: f32,
    fmod: f32,
    rate: f32,
    phase: f32,
    width: f32,
    second_formant: f32,
}

fn class_protos(cfg: &SpeechCfg, rng: &mut Pcg32) -> Vec<ClassProto> {
    (0..cfg.classes)
        .map(|_| ClassProto {
            f0: 2.0 + rng.uniform() * (cfg.f as f32 - 6.0),
            fmod: 1.0 + rng.uniform() * 4.0,
            rate: 0.5 + rng.uniform() * 2.5,
            phase: rng.uniform() * std::f32::consts::TAU,
            width: 0.8 + rng.uniform() * 1.6,
            second_formant: rng.uniform() * cfg.f as f32,
        })
        .collect()
}

struct Speaker {
    timbre: Vec<f32>,
    pitch_shift: f32,
    gain: f32,
    tempo: f32,
}

fn speakers(cfg: &SpeechCfg, rng: &mut Pcg32) -> Vec<Speaker> {
    let mut cache = None;
    (0..cfg.speakers)
        .map(|_| Speaker {
            timbre: (0..cfg.f)
                .map(|_| 0.25 * rng.normal(&mut cache))
                .collect(),
            pitch_shift: 1.2 * rng.normal(&mut cache),
            gain: 1.0 + 0.2 * rng.normal(&mut cache),
            tempo: 1.0 + 0.15 * rng.normal(&mut cache),
        })
        .collect()
}

/// Generate train + test. Utterances are distributed round-robin over
/// speakers with per-speaker class preferences (speakers do not say
/// every word equally often — mirrors SpeechCommands).
pub fn generate(
    cfg: &SpeechCfg,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> (Dataset, Dataset) {
    let mut rng = Pcg32::new(seed, 0x5350_4545_4348); // "SPEECH" stream
    let protos = class_protos(cfg, &mut rng);
    let spk = speakers(cfg, &mut rng);
    // per-speaker class preference (Dirichlet over classes)
    let prefs: Vec<Vec<f64>> = (0..cfg.speakers)
        .map(|_| rng.dirichlet(1.5, cfg.classes))
        .collect();

    let mut make = |n: usize, rng: &mut Pcg32| -> Dataset {
        let fl = cfg.t * cfg.f;
        let mut x = Vec::with_capacity(n * fl);
        let mut y = Vec::with_capacity(n);
        let mut group = Vec::with_capacity(n);
        let mut cache = None;
        for i in 0..n {
            let sid = i % cfg.speakers;
            let s = &spk[sid];
            // sample class from the speaker's preference
            let r = rng.uniform_f64();
            let mut acc = 0.0;
            let mut cl = cfg.classes - 1;
            for (c, &p) in prefs[sid].iter().enumerate() {
                acc += p;
                if r < acc {
                    cl = c;
                    break;
                }
            }
            let pr = &protos[cl];
            for tt in 0..cfg.t {
                let tf = tt as f32 * s.tempo;
                let center = pr.f0
                    + s.pitch_shift
                    + pr.fmod
                        * (pr.rate * tf * std::f32::consts::TAU
                            / cfg.t as f32
                            + pr.phase)
                            .sin();
                let env = (std::f32::consts::PI * (tt as f32 + 0.5)
                    / cfg.t as f32)
                    .sin();
                for ff in 0..cfg.f {
                    let d1 = (ff as f32 - center) / pr.width;
                    let d2 = (ff as f32 - pr.second_formant) / 2.0;
                    let v = s.gain
                        * env
                        * (2.0 * (-0.5 * d1 * d1).exp()
                            + 0.7 * (-0.5 * d2 * d2).exp())
                        + s.timbre[ff]
                        + cfg.noise * rng.normal(&mut cache);
                    x.push(v);
                }
            }
            y.push(cl as i32);
            group.push(sid as u32);
        }
        Dataset {
            x,
            y,
            feat_shape: vec![cfg.t, cfg.f],
            classes: cfg.classes,
            group,
        }
    };
    let train = make(n_train, &mut rng);
    let test = make(n_test, &mut rng);
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let cfg = SpeechCfg::new(12, 16);
        let (tr, te) = generate(&cfg, 128, 32, 1);
        assert_eq!(tr.len(), 128);
        assert_eq!(tr.feat_len(), 32 * 16);
        assert_eq!(te.feat_shape, vec![32, 16]);
        assert!(tr.y.iter().all(|&v| (0..12).contains(&v)));
    }

    #[test]
    fn speakers_cover_dataset() {
        let cfg = SpeechCfg::new(12, 16);
        let (tr, _) = generate(&cfg, 160, 16, 2);
        let mut seen = vec![false; 16];
        for &g in &tr.group {
            seen[g as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn deterministic() {
        let cfg = SpeechCfg::new(12, 8);
        let (a, _) = generate(&cfg, 64, 8, 7);
        let (b, _) = generate(&cfg, 64, 8, 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.group, b.group);
    }

    #[test]
    fn class_signal_present() {
        // energy-weighted frequency centroid should differ across classes
        let cfg = SpeechCfg::new(4, 8);
        let (tr, _) = generate(&cfg, 400, 8, 3);
        let mut cent = vec![0.0f64; 4];
        let mut cnt = vec![0.0f64; 4];
        for i in 0..tr.len() {
            let ex = tr.example(i);
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for tt in 0..cfg.t {
                for ff in 0..cfg.f {
                    let e = (ex[tt * cfg.f + ff] as f64).max(0.0);
                    num += e * ff as f64;
                    den += e;
                }
            }
            cent[tr.y[i] as usize] += num / den.max(1e-9);
            cnt[tr.y[i] as usize] += 1.0;
        }
        let c: Vec<f64> = cent
            .iter()
            .zip(&cnt)
            .map(|(s, n)| s / n.max(1.0))
            .collect();
        let spread = c.iter().cloned().fold(f64::MIN, f64::max)
            - c.iter().cloned().fold(f64::MAX, f64::min);
        assert!(spread > 0.3, "centroid spread {spread}");
    }
}
